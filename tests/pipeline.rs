//! End-to-end pipeline integration: pre-train -> zero-shot -> MeZO
//! fine-tune -> storage replay, all through real AOT artifacts. Uses a
//! scratch MEZO_RUNS dir so cached checkpoints elsewhere are untouched.
//! (Run serially: `cargo test --test pipeline -- --test-threads=1`.)
//! pjrt builds only — needs the compiled artifact runtime.
#![cfg(feature = "pjrt")]

use mezo::data::batch::sample_batch;
use mezo::data::tasks::{generate, GenOpts, Task};
use mezo::eval::Evaluator;
use mezo::model::params::ParamStore;
use mezo::optim::ft::{FtConfig, FtFlavor, FtOptimizer};
use mezo::optim::mezo::{MezoConfig, MezoSgd};
use mezo::rng::Pcg;
use mezo::runtime::{scalar_f32, vec_f32, Runtime};
use mezo::tokenizer::Vocab;
use mezo::train::batch_loss;
use mezo::train::pretrain::{artifact_name, pretrain_into, PretrainCfg};
use std::path::Path;

fn runtime() -> Runtime {
    Runtime::new(Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts").as_path()).unwrap()
}

#[test]
fn pretrain_reduces_lm_loss_and_mezo_reduces_task_loss() {
    let rt = runtime();
    let vocab = Vocab::standard();
    let grad = rt.load(&artifact_name("ar", "tiny", "grad", "full")).unwrap();
    let mut params = ParamStore::from_meta(&grad.meta);
    params.init(0);
    // short pre-training: loss must drop substantially from ln(512)=6.24
    let cfg = PretrainCfg { steps: 250, corpus_seqs: 512, ..Default::default() };
    let curve = pretrain_into(&rt, "ar", "tiny", &mut params, &cfg).unwrap();
    let (first, last) = (curve[0].1, curve.last().unwrap().1);
    assert!(first > 5.0, "init loss {}", first);
    assert!(last < first - 1.5, "pretraining barely moved: {} -> {}", first, last);

    // MeZO on sst2: train loss must drop without any backprop
    let loss_art = rt.load(&artifact_name("ar", "tiny", "loss", "full")).unwrap();
    let data = generate(Task::Sst2, &vocab, GenOpts { n_train: 64, ..Default::default() });
    let trainable = params.indices_of(&loss_art.meta.trainable);
    let mcfg = MezoConfig { lr: 1e-4, eps: 1e-3, ..Default::default() };
    let mut opt = MezoSgd::new(mcfg, trainable, 5);
    let mut rng = Pcg::new(1);
    let probe = sample_batch(&data.train, &mut rng, 8, 64, false);
    let l0 = batch_loss(&loss_art, &params, &probe).unwrap();
    for _ in 0..120 {
        let batch = sample_batch(&data.train, &mut rng, 8, 64, false);
        opt.step(&mut params, |p| batch_loss(&loss_art, p, &batch)).unwrap();
    }
    let l1 = batch_loss(&loss_art, &params, &probe).unwrap();
    assert!(l1 < l0, "MeZO did not reduce task loss: {} -> {}", l0, l1);
    assert_eq!(opt.history.len(), 120);
}

#[test]
fn ft_beats_zero_shot_on_sst2() {
    let rt = runtime();
    let vocab = Vocab::standard();
    let grad = rt.load(&artifact_name("ar", "tiny", "grad", "full")).unwrap();
    let loss_art = rt.load(&artifact_name("ar", "tiny", "loss", "full")).unwrap();
    let mut params = ParamStore::from_meta(&grad.meta);
    params.init(3);
    let cfg = PretrainCfg { steps: 1500, corpus_seqs: 1024, ..Default::default() };
    pretrain_into(&rt, "ar", "tiny", &mut params, &cfg).unwrap();

    let ev = Evaluator::new(loss_art, None, false);
    let data = generate(Task::Sst2, &vocab,
                        GenOpts { n_train: 128, n_test: 96, ..Default::default() });
    let zs = ev.evaluate(&params, Task::Sst2, &data.test).unwrap().score;

    let trainable = params.indices_of(&grad.meta.trainable);
    let fcfg = FtConfig { lr: 3e-4, flavor: FtFlavor::Adam, total_steps: 200, ..Default::default() };
    let mut opt = FtOptimizer::new(fcfg, trainable, &params);
    let mut rng = Pcg::new(2);
    for _ in 0..200 {
        let batch = sample_batch(&data.train, &mut rng, 8, 64, false);
        let out = grad.run(&params, Some(&batch), &[]).unwrap();
        let grads: Vec<Vec<f32>> = out[1..].iter().map(|l| vec_f32(l).unwrap()).collect();
        opt.apply(&mut params, &grads).unwrap();
    }
    let ft = ev.evaluate(&params, Task::Sst2, &data.test).unwrap().score;
    assert!(ft > zs + 0.05, "FT {} should beat zero-shot {}", ft, zs);
}

#[test]
fn lora_and_prefix_artifacts_train_only_their_parameters() {
    let rt = runtime();
    for tuning in ["lora", "prefix"] {
        let name = artifact_name("ar", "tiny", "loss", tuning);
        let art = rt.load(&name).unwrap();
        let mut params = ParamStore::from_meta(&art.meta);
        params.init(7);
        // trainables must be exactly the PEFT tensors
        for t in &art.meta.trainable {
            assert!(t.contains(".lora_") || t.contains(".prefix."), "{}", t);
        }
        let mut batch = mezo::data::batch::Batch::zeros(8, 64);
        for row in 0..8 {
            let seq: Vec<u32> = (0..24).map(|t| ((t * 3 + row) % 500 + 5) as u32).collect();
            batch.set_row(row, &seq, 1..seq.len(), false);
        }
        let l0 = scalar_f32(&art.run(&params, Some(&batch), &[]).unwrap()[0]).unwrap();
        // a MeZO step touching only PEFT params changes the loss
        let trainable = params.indices_of(&art.meta.trainable);
        let cfg = MezoConfig { lr: 1e-2, eps: 1e-2, ..Default::default() };
        let mut opt = MezoSgd::new(cfg, trainable, 9);
        for _ in 0..5 {
            opt.step(&mut params, |p| batch_loss(&art, p, &batch)).unwrap();
        }
        let l1 = scalar_f32(&art.run(&params, Some(&batch), &[]).unwrap()[0]).unwrap();
        assert!((l0 - l1).abs() > 1e-7, "{}: loss unchanged", tuning);
        // frozen base tensors are bit-identical
        let mut fresh = ParamStore::from_meta(&art.meta);
        fresh.init(7);
        for (spec, (a, b)) in params.specs.iter().zip(params.data.iter().zip(&fresh.data)) {
            if !art.meta.trainable.contains(&spec.name) {
                assert_eq!(a, b, "{} drifted", spec.name);
            }
        }
    }
}

#[test]
fn step_artifact_records_match_in_place_step_for_same_master_seed() {
    // the §Perf L3 fast path consumes the same master seed stream and must
    // produce the identical StepRecord trajectory as the in-place step()
    // (pgrads agree to float tolerance: run_perturbed computes θ+εz in the
    // staging buffer, step() perturbs in place — same z, same math, modulo
    // the in-place path's ±ε restore rounding)
    let rt = runtime();
    let loss_art = rt.load(&artifact_name("ar", "tiny", "loss", "full")).unwrap();
    let mut pa = ParamStore::from_meta(&loss_art.meta);
    pa.init(21);
    let mut pb = pa.clone();
    let trainable = pa.indices_of(&loss_art.meta.trainable);
    let cfg = MezoConfig { lr: 1e-4, eps: 1e-3, ..Default::default() };
    let mut opt_step = MezoSgd::new(cfg.clone(), trainable.clone(), 77);
    let mut opt_fast = MezoSgd::new(cfg, trainable, 77);
    let mut batch = mezo::data::batch::Batch::zeros(8, 64);
    for row in 0..8 {
        let seq: Vec<u32> = (0..28).map(|t| ((t * 5 + row * 2) % 500 + 5) as u32).collect();
        batch.set_row(row, &seq, 1..seq.len(), false);
    }
    let mut scratch = Vec::new();
    for _ in 0..5 {
        opt_step.step(&mut pa, |p| batch_loss(&loss_art, p, &batch)).unwrap();
        opt_fast.step_artifact(&mut pb, &loss_art, &batch, &mut scratch).unwrap();
    }
    assert_eq!(opt_step.history.len(), opt_fast.history.len());
    for (a, b) in opt_step.history.iter().zip(&opt_fast.history) {
        assert_eq!(a.seed, b.seed, "same master seed stream");
        assert_eq!(a.lr, b.lr);
        assert!(
            (a.pgrad - b.pgrad).abs() <= 1e-3 * a.pgrad.abs().max(1.0),
            "pgrad diverged: {} vs {}",
            a.pgrad,
            b.pgrad
        );
    }
}

#[test]
fn run_perturbed_rejects_mis_shaped_batch() {
    // satellite: run_perturbed skipped the (b, s) ABI check run() performs
    let rt = runtime();
    let loss_art = rt.load(&artifact_name("ar", "tiny", "loss", "full")).unwrap();
    let mut params = ParamStore::from_meta(&loss_art.meta);
    params.init(2);
    let mask = vec![true; params.specs.len()];
    let mut scratch = Vec::new();
    let bad = mezo::data::batch::Batch::zeros(4, 32); // artifact is (8, 64)
    let err = loss_art
        .run_perturbed(&params, &mask, 1, 1e-3, Some(&bad), &mut scratch)
        .unwrap_err();
    assert!(err.to_string().contains("batch shape"), "{}", err);
}

#[test]
fn fused_step_artifact_matches_semantics() {
    let rt = runtime();
    let fused = rt.load("ar_tiny_full_fused_b8_s64").unwrap();
    let mut params = ParamStore::from_meta(&fused.meta);
    params.init(11);
    let mut batch = mezo::data::batch::Batch::zeros(8, 64);
    for row in 0..8 {
        let seq: Vec<u32> = (0..30).map(|t| ((t * 7 + row * 3) % 500 + 5) as u32).collect();
        batch.set_row(row, &seq, 1..seq.len(), false);
    }
    let extras = [
        mezo::runtime::i32_literal(&[1], &[13]).unwrap(),
        mezo::runtime::f32_literal(&[1], &[1e-3]).unwrap(),
        mezo::runtime::f32_literal(&[1], &[1e-4]).unwrap(),
    ];
    let out = fused.run(&params, Some(&batch), &extras).unwrap();
    let n = fused.meta.trainable.len();
    assert_eq!(out.len(), n + 3);
    let lp = scalar_f32(&out[n]).unwrap();
    let lm = scalar_f32(&out[n + 1]).unwrap();
    let pg = scalar_f32(&out[n + 2]).unwrap();
    assert!((pg - (lp - lm) / 2e-3).abs() < 2e-2 * pg.abs().max(1.0), "pgrad identity");
    // updated params differ and are finite
    let new0 = vec_f32(&out[0]).unwrap();
    assert!(new0.iter().all(|x| x.is_finite()));
    assert_ne!(new0, params.data[0]);
}
