//! Property suite for the multi-tenant serving cache (`mezo::serve`).
//!
//! The single invariant under test: **cache state never moves a bit**.
//! Whatever the capacity (0/1/N), the eviction order, the request
//! interleaving, the replay mode (dense / seed-batched / masked /
//! sharded), or the thread count (`scripts/verify.sh` re-runs this file
//! under the `MEZO_THREADS` × `MEZO_SIMD` matrix), every served store is
//! `to_bits()`-identical to a fresh dense replay of the user's log — and
//! the sparse-log digest guards fire on every request, hit path or miss
//! path, because errors are never cached.

use mezo::model::meta::TensorDesc;
use mezo::model::params::ParamStore;
use mezo::optim::mezo::StepRecord;
use mezo::rng::Pcg;
use mezo::serve::{ServeConfig, ServeStore, UserLog};
use mezo::shard::ShardPlan;
use mezo::storage::Trajectory;
use mezo::util::prop::{ensure, forall};
use mezo::zkernel::SparseMask;
use std::sync::{Arc, Mutex};

fn base_store(seed: u64) -> ParamStore {
    let specs = vec![
        TensorDesc { name: "emb".into(), shape: vec![257], dtype: "f32".into() },
        TensorDesc { name: "w".into(), shape: vec![130], dtype: "f32".into() },
    ];
    let mut p = ParamStore::from_specs(specs);
    p.init(seed);
    p
}

fn names() -> Vec<String> {
    vec!["emb".into(), "w".into()]
}

fn random_records(rng: &mut Pcg, n: usize) -> Vec<StepRecord> {
    (0..n)
        .map(|_| StepRecord {
            seed: rng.next_u64(),
            pgrad: (rng.next_f32() - 0.5) * 0.1,
            lr: 1e-3,
        })
        .collect()
}

fn bits(p: &ParamStore) -> Vec<u32> {
    p.data.iter().flatten().map(|x| x.to_bits()).collect()
}

/// Reference result: fresh base copy + sequential dense replay.
fn dense_reference(base: &ParamStore, trainable: Vec<String>, recs: &[StepRecord]) -> Vec<u32> {
    let mut p = base.clone();
    Trajectory::from_run(trainable, recs).replay(&mut p);
    bits(&p)
}

#[test]
fn prop_served_bits_equal_fresh_dense_replay_under_random_traffic() {
    forall(
        25,
        0x5E21,
        |rng| {
            let capacity = [0usize, 1, 2 + rng.below(6)][rng.below(3)];
            let n_users = 3 + rng.below(6);
            let seed = rng.next_u64();
            // request script: (user, append_first) pairs
            let script: Vec<(usize, bool)> = (0..24)
                .map(|_| (rng.below(n_users), rng.below(5) == 0))
                .collect();
            (capacity, n_users, seed, script)
        },
        |(capacity, n_users, seed, script)| {
            let mut rng = Pcg::new(seed.wrapping_add(1));
            let base = base_store(*seed);
            let plan = Arc::new(ShardPlan::new(&base, 2).expect("plan"));
            let mask = Arc::new(SparseMask::full(&base, &[0, 1]));
            let mut serve =
                ServeStore::new(base.clone(), ServeConfig { cache_capacity: *capacity });
            let mut logs: Vec<Vec<StepRecord>> = Vec::new();
            for u in 0..*n_users {
                let n_recs = rng.below(5);
                let recs = random_records(&mut rng, n_recs);
                // rotate replay modes; a full mask is bitwise dense, so
                // the dense reference stays valid for every mode
                let log = Trajectory::from_run(names(), &recs);
                let ulog = match u % 4 {
                    0 => UserLog::dense(log),
                    1 => UserLog::dense_batched(log, 1),
                    2 => UserLog::masked(
                        log.with_mask_digest(mask.digest()),
                        Arc::clone(&mask),
                    ),
                    _ => UserLog::sharded(log, Arc::clone(&plan)),
                };
                serve.admit(u as u64, ulog).map_err(|e| e.to_string())?;
                logs.push(recs);
            }
            for &(u, append) in script {
                if append {
                    let extra = random_records(&mut rng, 1);
                    serve.append_steps(u as u64, &extra).map_err(|e| e.to_string())?;
                    logs[u].extend(extra);
                }
                let served = serve.get(u as u64).map_err(|e| e.to_string())?;
                let want = dense_reference(&base, names(), &logs[u]);
                ensure(
                    bits(&served) == want,
                    format!("user {} served bits != dense reference (cap {})", u, capacity),
                )?;
            }
            // the cache respects its bound at all times
            ensure(
                serve.cache_len() <= *capacity,
                format!("cache {} exceeds capacity {}", serve.cache_len(), capacity),
            )
        },
    );
}

#[test]
fn prop_arbitrary_eviction_orders_cannot_move_bits() {
    // capacity 1 forces an eviction on every user switch; the request
    // order (and therefore the eviction order) is arbitrary
    forall(
        20,
        0x5E22,
        |rng| {
            let seed = rng.next_u64();
            let order: Vec<usize> = (0..30).map(|_| rng.below(4)).collect();
            (seed, order)
        },
        |(seed, order)| {
            let mut rng = Pcg::new(*seed);
            let base = base_store(*seed);
            let mut serve = ServeStore::new(base.clone(), ServeConfig { cache_capacity: 1 });
            let mut logs = Vec::new();
            for u in 0..4u64 {
                let n_recs = 1 + rng.below(4);
                let recs = random_records(&mut rng, n_recs);
                serve
                    .admit(u, UserLog::dense(Trajectory::from_run(names(), &recs)))
                    .map_err(|e| e.to_string())?;
                logs.push(recs);
            }
            for &u in order {
                let served = serve.get(u as u64).map_err(|e| e.to_string())?;
                let want = dense_reference(&base, names(), &logs[u]);
                ensure(bits(&served) == want, format!("user {} drifted after eviction", u))?;
            }
            Ok(())
        },
    );
}

#[test]
fn capacity_sweep_0_1_n_serves_identical_bits() {
    let mut rng = Pcg::new(77);
    let base = base_store(77);
    let logs: Vec<Vec<StepRecord>> =
        (0..6).map(|_| random_records(&mut rng, 3)).collect();
    let script: Vec<usize> = (0..40).map(|_| rng.below(6)).collect();
    let mut per_capacity: Vec<Vec<Vec<u32>>> = Vec::new();
    for cap in [0usize, 1, 4] {
        let mut serve = ServeStore::new(base.clone(), ServeConfig { cache_capacity: cap });
        for (u, recs) in logs.iter().enumerate() {
            serve
                .admit(u as u64, UserLog::dense(Trajectory::from_run(names(), recs)))
                .unwrap();
        }
        let served: Vec<Vec<u32>> =
            script.iter().map(|&u| bits(&serve.get(u as u64).unwrap())).collect();
        if cap == 4 {
            assert!(serve.stats().hits > 0, "a working-set cache must hit");
        }
        if cap == 0 {
            assert_eq!(serve.stats().hits, 0, "capacity 0 disables the cache");
        }
        per_capacity.push(served);
    }
    assert_eq!(per_capacity[0], per_capacity[1]);
    assert_eq!(per_capacity[0], per_capacity[2]);
}

#[test]
fn concurrent_same_user_requests_share_one_materialization() {
    let mut rng = Pcg::new(88);
    let base = base_store(88);
    let recs = random_records(&mut rng, 4);
    let want = dense_reference(&base, names(), &recs);
    let mut serve = ServeStore::new(base, ServeConfig { cache_capacity: 4 });
    serve.admit(5, UserLog::dense(Trajectory::from_run(names(), &recs))).unwrap();
    let serve = Mutex::new(serve);
    let n_threads = 8;
    let gets_per_thread = 16;
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..n_threads)
            .map(|_| {
                s.spawn(|| {
                    let mut got: Vec<Arc<ParamStore>> = Vec::new();
                    for _ in 0..gets_per_thread {
                        got.push(serve.lock().unwrap().get(5).unwrap());
                    }
                    got
                })
            })
            .collect();
        for h in handles {
            for arc in h.join().unwrap() {
                assert_eq!(bits(&arc), want);
            }
        }
    });
    let st = serve.lock().unwrap().stats();
    assert_eq!(st.requests, n_threads * gets_per_thread);
    // one replay total: every other request shared the cached Arc
    assert_eq!(st.materializations, 1);
    assert_eq!(st.hits, n_threads * gets_per_thread - 1);
}

#[test]
fn sparse_log_digest_guard_fires_on_every_request_through_the_cache() {
    let mut rng = Pcg::new(99);
    let base = base_store(99);
    let mask = Arc::new(SparseMask::full(&base, &[0, 1]));
    let sparse_recs = random_records(&mut rng, 3);
    let dense_recs = random_records(&mut rng, 2);
    let mut serve = ServeStore::new(base.clone(), ServeConfig { cache_capacity: 2 });
    // user 1: sparse log, NO mask attached -> dense materialization refused
    serve
        .admit(
            1,
            UserLog::dense(
                Trajectory::from_run(names(), &sparse_recs).with_mask_digest(mask.digest()),
            ),
        )
        .unwrap();
    // user 2: healthy dense neighbor (keeps the cache busy in between)
    serve
        .admit(2, UserLog::dense(Trajectory::from_run(names(), &dense_recs)))
        .unwrap();
    for _ in 0..3 {
        let err = serve.get(1).unwrap_err();
        assert!(err.to_string().contains("sparse log"), "{}", err);
        serve.get(2).unwrap();
    }
    assert_eq!(serve.stats().hits, 2, "only user 2's requests may hit");
    // attaching the recorded mask heals the tenant; a full mask replays
    // bitwise-dense, so the dense reference still pins the result
    serve
        .admit(
            1,
            UserLog::masked(
                Trajectory::from_run(names(), &sparse_recs).with_mask_digest(mask.digest()),
                mask,
            ),
        )
        .unwrap();
    assert_eq!(bits(&serve.get(1).unwrap()), dense_reference(&base, names(), &sparse_recs));
}
