//! Acceptance suite for the block-quantized SensZOQ parameter store
//! behind the unified `Theta` API: quantize→dequantize round-trips stay
//! within the pinned per-block bound, masked (overlay) coordinates are
//! `to_bits()`-identical to the dense path through kernels, optimizer
//! steps, trajectory replay and serving, and none of it moves across
//! thread counts or dispatch strategies. `scripts/verify.sh` re-runs
//! this file under the full `MEZO_THREADS` × `MEZO_SIMD` matrix.

use mezo::model::meta::TensorDesc;
use mezo::model::params::ParamStore;
use mezo::model::quant::QuantStore;
use mezo::model::Theta;
use mezo::optim::fzoo::{Fzoo, FzooConfig};
use mezo::optim::mezo::{MezoConfig, MezoSgd, StepRecord};
use mezo::rng::{GaussianStream, Pcg};
use mezo::serve::{ServeConfig, ServeStore, UserLog};
use mezo::storage::Trajectory;
use mezo::util::prop::{ensure, forall};
use mezo::zkernel::{QBits, Sensitivity, SparseMask, ZEngine, QBLOCK};
use std::sync::Arc;

fn store_with(seed: u64, shapes: &[(&str, usize)]) -> ParamStore {
    let specs = shapes
        .iter()
        .map(|(n, l)| TensorDesc { name: (*n).into(), shape: vec![*l], dtype: "f32".into() })
        .collect();
    let mut p = ParamStore::from_specs(specs);
    p.init(seed);
    p
}

/// The bit patterns of every masked coordinate, in mask order.
fn masked_bits(p: &ParamStore, mask: &SparseMask) -> Vec<u32> {
    (0..p.specs.len())
        .flat_map(|ti| {
            mask.indices(ti).iter().map(move |&i| p.data[ti][i as usize].to_bits())
        })
        .collect()
}

#[test]
fn prop_quantize_dequantize_roundtrips_within_the_pinned_bound() {
    forall(
        60,
        71,
        |rng| {
            let bits = if rng.below(2) == 0 { QBits::Int8 } else { QBits::Int4 };
            // deliberately unaligned lengths, including sub-block tensors
            let len = rng.below(5 * QBLOCK) + 1;
            (bits, len, rng.next_u64())
        },
        |&(bits, len, seed)| {
            let p = store_with(seed, &[("w", len)]);
            let q = QuantStore::quantize(&p, bits, None).map_err(|e| e.to_string())?;
            let d = q.to_dense();
            let bound = q.dequant_error_bound();
            for (j, (a, b)) in p.data[0].iter().zip(&d.data[0]).enumerate() {
                ensure(
                    (a - b).abs() <= bound,
                    format!("{:?} len={} j={}: |{} - {}| > {}", bits, len, j, a, b, bound),
                )?;
            }
            Ok(())
        },
    );
}

#[test]
fn all_zero_and_single_outlier_blocks_roundtrip_within_their_scale() {
    for bits in [QBits::Int8, QBits::Int4] {
        let len = 3 * QBLOCK + 17; // unaligned tail block
        let mut p = store_with(5, &[("w", len)]);
        for v in &mut p.data[0][QBLOCK..2 * QBLOCK] {
            *v = 0.0; // an all-zero block quantizes to scale 0
        }
        p.data[0][2 * QBLOCK + 3] = 1000.0; // a single outlier owns its block's scale
        let q = QuantStore::quantize(&p, bits, None).unwrap();
        let d = q.to_dense();
        for j in QBLOCK..2 * QBLOCK {
            assert_eq!(
                d.data[0][j].to_bits(),
                0.0f32.to_bits(),
                "{:?}: zero block must dequantize to exact zero at {}",
                bits,
                j
            );
        }
        // the outlier block's half-step bound: 0.5 · absmax / q_max
        let worst = 0.5 * 1000.0 / bits.q_max() as f32;
        for (j, (a, b)) in p.data[0].iter().zip(&d.data[0]).enumerate() {
            assert!(
                (a - b).abs() <= worst + 1e-6,
                "{:?} j={}: {} vs {}",
                bits,
                j,
                a,
                b
            );
        }
    }
}

#[test]
fn prop_masked_coordinates_survive_quantization_bitwise() {
    forall(
        40,
        72,
        |rng| {
            let bits = if rng.below(2) == 0 { QBits::Int8 } else { QBits::Int4 };
            let len = rng.below(4 * QBLOCK) + 1;
            let k = rng.below(len) + 1;
            (bits, len, k, rng.next_u64())
        },
        |&(bits, len, k, seed)| {
            let p = store_with(seed, &[("w", len)]);
            let mask = SparseMask::top_k(&p, &[0], k, Sensitivity::Magnitude)
                .map_err(|e| e.to_string())?;
            let q = QuantStore::quantize(&p, bits, Some(&mask)).map_err(|e| e.to_string())?;
            let d = q.to_dense();
            for &i in mask.indices(0) {
                ensure(
                    p.data[0][i as usize].to_bits() == d.data[0][i as usize].to_bits(),
                    format!("{:?} i={}: overlay coordinate moved", bits, i),
                )?;
            }
            Ok(())
        },
    );
}

#[test]
fn masked_kernels_on_a_quant_store_match_dense_bitwise_across_dispatch() {
    // one tensor big enough that the threaded dense kernels actually fan
    // out, one small; the quant path must agree bit for bit either way
    let p = store_with(31, &[("emb", 70_000), ("w", 517)]);
    let mask = SparseMask::top_k(&p, &[0, 1], 4_000, Sensitivity::Magnitude).unwrap();
    let engines = [
        ZEngine::with_threads(1),
        ZEngine::with_threads(2),
        ZEngine::with_threads(8),
        ZEngine::with_threads_scoped(8),
    ];
    let stream = GaussianStream::new(99);
    let zs: Vec<(GaussianStream, f32)> = (0..3)
        .map(|i| (GaussianStream::new(200 + i), 0.01 * (i as f32 + 1.0)))
        .collect();
    let mut reference: Option<Vec<u32>> = None;
    for engine in &engines {
        for bits in [QBits::Int8, QBits::Int4] {
            let mut dense = p.clone();
            let mut quant = QuantStore::quantize(&p, bits, Some(&mask)).unwrap();
            for ti in 0..2 {
                dense.axpy_z_masked(engine, ti, stream, mask.indices(ti), 0.02);
                quant.axpy_z_masked(engine, ti, stream, mask.indices(ti), 0.02);
                dense.multi_axpy_z_masked(engine, ti, &zs, mask.indices(ti));
                quant.multi_axpy_z_masked(engine, ti, &zs, mask.indices(ti));
                dense.sgd_update_masked(engine, ti, stream, mask.indices(ti), 1e-2, 0.3, 1e-4);
                quant.sgd_update_masked(engine, ti, stream, mask.indices(ti), 1e-2, 0.3, 1e-4);
            }
            let got = masked_bits(&quant.to_dense(), &mask);
            assert_eq!(got, masked_bits(&dense, &mask), "{:?}: quant != dense", bits);
            let r = reference.get_or_insert_with(|| got.clone());
            assert_eq!(&got, r, "{:?}: dispatch variation moved bits", bits);
        }
    }
}

#[test]
fn unmasked_quant_kernels_stay_within_the_pinned_dequant_bound() {
    let base = store_with(61, &[("w", 2 * QBLOCK + 13)]);
    for bits in [QBits::Int8, QBits::Int4] {
        let mut q = QuantStore::quantize(&base, bits, None).unwrap();
        // the exact update applies to the DEQUANTIZED values; the store
        // may only add one requantization half-step on top of that
        let mut exact = q.to_dense();
        let engine = ZEngine::default();
        let stream = GaussianStream::new(7);
        q.axpy_z(&engine, 0, stream, 0.02);
        exact.axpy_z(&engine, 0, stream, 0.02);
        let bound = q.dequant_error_bound();
        let d = q.to_dense();
        for (j, (a, b)) in exact.data[0].iter().zip(&d.data[0]).enumerate() {
            assert!(
                (a - b).abs() <= bound,
                "{:?} j={}: |{} - {}| > {}",
                bits,
                j,
                a,
                b,
                bound
            );
        }
    }
}

#[test]
fn mezo_sgd_masked_stepping_on_a_quant_store_is_bitwise_the_dense_run() {
    let base = store_with(41, &[("emb", 300), ("w", 517)]);
    let mask = SparseMask::top_k(&base, &[0, 1], 64, Sensitivity::Magnitude).unwrap();
    let cfg = MezoConfig { lr: 1e-2, eps: 1e-3, ..Default::default() };

    // the loss sequence is a deterministic script shared by both runs, so
    // every (seed, pgrad, lr) record — and thus every masked update — is
    // identical; only the store representation differs
    let mut dense = base.clone();
    let mut opt_d = MezoSgd::new(cfg.clone(), vec![0, 1], 77);
    opt_d.mask = Some(mask.clone());
    let mut script = Pcg::new(1234);
    for _ in 0..25 {
        opt_d.step(&mut dense, |_| Ok(script.next_f32() - 0.5)).unwrap();
    }

    for bits in [QBits::Int8, QBits::Int4] {
        let mut q = QuantStore::quantize(&base, bits, Some(&mask)).unwrap();
        let before = q.to_dense();
        let mut opt_q = MezoSgd::new(cfg.clone(), vec![0, 1], 77);
        opt_q.mask = Some(mask.clone());
        let mut script = Pcg::new(1234);
        for _ in 0..25 {
            opt_q.step(&mut q, |_| Ok(script.next_f32() - 0.5)).unwrap();
        }
        assert_eq!(opt_q.history, opt_d.history, "{:?}: records diverged", bits);
        let after = q.to_dense();
        assert_eq!(
            masked_bits(&after, &mask),
            masked_bits(&dense, &mask),
            "{:?}: masked coordinates diverged",
            bits
        );
        // masked stepping must never move an unmasked (code-held) coordinate
        for ti in 0..2 {
            let idxs = mask.indices(ti);
            for j in 0..after.data[ti].len() {
                if idxs.binary_search(&(j as u32)).is_err() {
                    assert_eq!(
                        after.data[ti][j].to_bits(),
                        before.data[ti][j].to_bits(),
                        "{:?}: unmasked coordinate ({}, {}) moved",
                        bits,
                        ti,
                        j
                    );
                }
            }
        }
    }
}

#[test]
fn fzoo_masked_stepping_on_a_quant_store_is_bitwise_the_dense_run() {
    let base = store_with(42, &[("emb", 300), ("w", 517)]);
    let mask = SparseMask::top_k(&base, &[0, 1], 96, Sensitivity::Magnitude).unwrap();
    let cfg = FzooConfig { lr: 1e-2, eps: 1e-3, n: 3, ..Default::default() };

    let mut dense = base.clone();
    let mut opt_d = Fzoo::new(cfg.clone(), vec![0, 1], 88);
    opt_d.mask = Some(mask.clone());
    let mut script = Pcg::new(4321);
    for _ in 0..15 {
        opt_d.step(&mut dense, |_| Ok(script.next_f32())).unwrap();
    }

    for bits in [QBits::Int8, QBits::Int4] {
        let mut q = QuantStore::quantize(&base, bits, Some(&mask)).unwrap();
        let mut opt_q = Fzoo::new(cfg.clone(), vec![0, 1], 88);
        opt_q.mask = Some(mask.clone());
        let mut script = Pcg::new(4321);
        for _ in 0..15 {
            opt_q.step(&mut q, |_| Ok(script.next_f32())).unwrap();
        }
        assert_eq!(opt_q.history, opt_d.history, "{:?}: records diverged", bits);
        assert_eq!(
            masked_bits(&q.to_dense(), &mask),
            masked_bits(&dense, &mask),
            "{:?}: masked coordinates diverged",
            bits
        );
    }
}

#[test]
fn masked_replay_on_a_quant_store_matches_dense_across_modes_and_threads() {
    let base = store_with(51, &[("emb", 300), ("w", 517)]);
    let mask = SparseMask::top_k(&base, &[0, 1], 96, Sensitivity::Magnitude).unwrap();
    let mut traj =
        Trajectory::new(vec!["emb".into(), "w".into()]).with_mask_digest(mask.digest());
    for i in 0..12u64 {
        traj.records.push(StepRecord {
            seed: 500 + i,
            pgrad: 0.05 * i as f32 - 0.25,
            lr: 2e-3,
        });
    }
    let mut dense = base.clone();
    traj.replay_masked_with(&ZEngine::with_threads(1), &mut dense, &mask).unwrap();
    let want = masked_bits(&dense, &mask);
    for engine in [
        ZEngine::with_threads(1),
        ZEngine::with_threads(8),
        ZEngine::with_threads_scoped(8),
    ] {
        for bits in [QBits::Int8, QBits::Int4] {
            let mut seq = QuantStore::quantize(&base, bits, Some(&mask)).unwrap();
            traj.replay_masked_with(&engine, &mut seq, &mask).unwrap();
            assert_eq!(masked_bits(&seq.to_dense(), &mask), want, "{:?} sequential", bits);
            let mut bat = QuantStore::quantize(&base, bits, Some(&mask)).unwrap();
            traj.replay_batched_masked_with(&engine, &mut bat, &mask, 3).unwrap();
            assert_eq!(masked_bits(&bat.to_dense(), &mask), want, "{:?} batched", bits);
        }
    }
}

#[test]
fn serving_from_a_quant_base_passes_the_masked_bitwise_gate() {
    let base = store_with(71, &[("emb", 300), ("w", 517)]);
    let mask =
        Arc::new(SparseMask::top_k(&base, &[0, 1], 128, Sensitivity::Magnitude).unwrap());
    let mut rng = Pcg::new(72);
    let recs: Vec<StepRecord> = (0..6)
        .map(|_| StepRecord {
            seed: rng.next_u64(),
            pgrad: rng.next_f32() - 0.5,
            lr: 1e-3,
        })
        .collect();
    let log = Trajectory::from_run(vec!["emb".into(), "w".into()], &recs)
        .with_mask_digest(mask.digest());

    let mut dense_srv = ServeStore::new(base.clone(), ServeConfig::default());
    dense_srv.admit(1, UserLog::masked(log.clone(), Arc::clone(&mask))).unwrap();
    let want = dense_srv.get(1).unwrap();

    for bits in [QBits::Int8, QBits::Int4] {
        let q = QuantStore::quantize(&base, bits, Some(&mask)).unwrap();
        let mut srv = ServeStore::new_quant(q, ServeConfig::default());
        srv.admit(1, UserLog::masked(log.clone(), Arc::clone(&mask))).unwrap();
        let got = srv.get(1).unwrap();
        // the serving gate: every masked coordinate of a tenant served
        // from the quantized base is bitwise the dense-base serving result
        assert_eq!(masked_bits(&got, &mask), masked_bits(&want, &mask), "{:?}", bits);
        // and the cached path is bitwise the uncached reference path
        assert_eq!(
            masked_bits(&got, &mask),
            masked_bits(&srv.materialize_fresh(1).unwrap(), &mask)
        );
    }
}
