//! Property-based tests over the coordinator's invariants (routing of
//! loss masks, in-place state management, serialization, metrics).

use mezo::data::batch::Batch;
use mezo::data::tasks::{generate, GenOpts, TaskType, ALL_TASKS};
use mezo::eval::metrics;
use mezo::model::meta::TensorDesc;
use mezo::model::params::ParamStore;
use mezo::optim::mezo::{perturb_tensors, StepRecord};
use mezo::rng::{GaussianStream, Pcg};
use mezo::storage::Trajectory;
use mezo::tokenizer::Vocab;
use mezo::util::json::Json;
use mezo::util::prop::{ensure, forall};

#[test]
fn prop_json_roundtrip() {
    fn gen_json(rng: &mut Pcg, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => Json::Num((rng.next_f64() * 2e6).round() / 1e3 - 1000.0),
            3 => {
                let len = rng.below(8);
                Json::Str(
                    (0..len)
                        .map(|_| {
                            *rng.choice(&['a', 'Z', '9', ' ', '"', '\\', '\n', 'é'])
                        })
                        .collect(),
                )
            }
            4 => Json::Arr((0..rng.below(4)).map(|_| gen_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(4))
                    .map(|i| (format!("k{}", i), gen_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    forall(
        200,
        10,
        |rng| gen_json(rng, 3),
        |j| {
            let s = j.to_string();
            let back = Json::parse(&s).map_err(|e| format!("parse: {}", e))?;
            ensure(&back == j, format!("roundtrip mismatch: {}", s))
        },
    );
}

#[test]
fn prop_gaussian_stream_random_access_equals_sequential() {
    forall(
        100,
        11,
        |rng| (rng.next_u64(), rng.below(1000) as u64, rng.below(64) + 1),
        |&(seed, offset, len)| {
            let g = GaussianStream::new(seed);
            let mut buf = vec![0.0f32; len];
            g.fill(&mut buf, offset);
            for (j, &v) in buf.iter().enumerate() {
                if v != g.z(offset + j as u64) {
                    return Err("fill != z".into());
                }
                if !v.is_finite() {
                    return Err("non-finite z".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_perturb_restore_roundtrip_any_shape() {
    forall(
        60,
        12,
        |rng| {
            let n_tensors = rng.below(4) + 1;
            let shapes: Vec<usize> = (0..n_tensors).map(|_| rng.below(200) + 1).collect();
            (rng.next_u64(), shapes, (rng.next_f32() * 0.1).max(1e-5))
        },
        |(seed, shapes, eps)| {
            let specs: Vec<TensorDesc> = shapes
                .iter()
                .enumerate()
                .map(|(i, &n)| TensorDesc {
                    name: format!("t{}", i),
                    shape: vec![n],
                    dtype: "f32".into(),
                })
                .collect();
            let mut p = ParamStore::from_specs(specs);
            p.init(*seed);
            let before = p.data.clone();
            let all: Vec<usize> = (0..p.specs.len()).collect();
            perturb_tensors(&mut p, &all, *seed ^ 7, *eps);
            perturb_tensors(&mut p, &all, *seed ^ 7, -2.0 * eps);
            perturb_tensors(&mut p, &all, *seed ^ 7, *eps);
            for (a, b) in p.data.iter().flatten().zip(before.iter().flatten()) {
                if (a - b).abs() > 1e-5 {
                    return Err(format!("not restored: {} vs {}", a, b));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_batch_masks_are_consistent_across_tasks() {
    // loss positions must always be attended; padding never supervised;
    // AR targets are the left-shifted input on supervised positions.
    let vocab = Vocab::standard();
    forall(
        60,
        13,
        |rng| {
            let task = *rng.choice(&ALL_TASKS);
            (task, rng.next_u64() % 1000)
        },
        |&(task, seed)| {
            let data = generate(task, &vocab, GenOpts { seed, n_train: 6, n_val: 1, n_test: 1, ..Default::default() });
            for mlm in [false, true] {
                if mlm && task.task_type() != TaskType::Classification {
                    continue; // MLM path is classification-only (single-token)
                }
                for ex in &data.train {
                    let (seq, range) = ex.filled();
                    if mlm && range.len() != 1 {
                        continue;
                    }
                    let mut b = Batch::zeros(1, 64);
                    b.set_row(0, &seq, range.clone(), mlm);
                    for t in 0..64 {
                        if b.loss_mask[t] > 0.0 && b.attn_mask[t] == 0.0 {
                            return Err(format!("{}: loss on padding at {}", task.name(), t));
                        }
                        if !mlm && b.loss_mask[t] > 0.0 {
                            let predicted = b.targets[t] as u32;
                            if seq.get(t + 1) != Some(&predicted) {
                                return Err(format!("{}: AR target misaligned", task.name()));
                            }
                        }
                    }
                    let n_loss: f32 = b.loss_mask.iter().sum();
                    if n_loss < 1.0 {
                        return Err(format!("{}: empty loss mask", task.name()));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_trajectory_roundtrip_and_replay_determinism() {
    forall(
        30,
        14,
        |rng| {
            let n = rng.below(40) + 1;
            let records: Vec<StepRecord> = (0..n)
                .map(|_| StepRecord {
                    seed: rng.next_u64(),
                    pgrad: rng.normal() as f32,
                    lr: rng.next_f32() * 1e-2,
                })
                .collect();
            (records, rng.next_u64())
        },
        |(records, seed)| {
            let path = std::env::temp_dir().join(format!("mezo_prop_traj_{}.bin", seed));
            let traj = Trajectory::from_run(vec!["w".into()], records);
            traj.save(&path).map_err(|e| e.to_string())?;
            let back = Trajectory::load(&path).map_err(|e| e.to_string())?;
            std::fs::remove_file(&path).ok();
            ensure(back == traj, "trajectory roundtrip")?;
            // replay twice from the same init => identical params
            let specs = vec![TensorDesc { name: "w".into(), shape: vec![32], dtype: "f32".into() }];
            let mut a = ParamStore::from_specs(specs.clone());
            a.init(*seed);
            let mut b = ParamStore::from_specs(specs);
            b.init(*seed);
            traj.replay(&mut a);
            traj.replay(&mut b);
            ensure(a.data == b.data, "replay determinism")
        },
    );
}

#[test]
fn prop_metrics_bounds_and_symmetry() {
    forall(
        200,
        15,
        |rng| {
            let n = rng.below(12) + 1;
            let pred: Vec<u32> = (0..n).map(|_| rng.below(8) as u32).collect();
            let m = rng.below(12) + 1;
            let gold: Vec<u32> = (0..m).map(|_| rng.below(8) as u32).collect();
            (pred, gold)
        },
        |(pred, gold)| {
            let f = metrics::token_f1(pred, gold);
            ensure((0.0..=1.0).contains(&f), "f1 in [0,1]")?;
            // token-F1 is symmetric in (pred, gold)
            let g = metrics::token_f1(gold, pred);
            ensure((f - g).abs() < 1e-12, "f1 symmetry")?;
            ensure(
                metrics::exact_match(pred, gold) <= 1.0
                    && (metrics::exact_match(pred, pred) - 1.0).abs() < 1e-12,
                "em identity",
            )
        },
    );
}

#[test]
fn prop_examples_fit_budget_for_every_task_and_seed() {
    let vocab = Vocab::standard();
    forall(
        45,
        16,
        |rng| (*rng.choice(&ALL_TASKS), rng.next_u64() % 5000),
        |&(task, seed)| {
            let data = generate(task, &vocab, GenOpts { seed, n_train: 12, n_val: 4, n_test: 4, ..Default::default() });
            for ex in data.train.iter().chain(&data.val).chain(&data.test) {
                let worst = ex
                    .candidates
                    .iter()
                    .map(|c| c.len())
                    .max()
                    .unwrap_or(ex.answer.len());
                let total = ex.context.len() + worst + ex.suffix.len();
                if total + 1 > 64 {
                    return Err(format!("{} seq {} > 64", task.name(), total));
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// Sparse SensZOQ mask properties (ISSUE 3). Every masked kernel has an
// exact dense oracle: a full mask must reproduce the dense kernel
// `to_bits()`-identically at any thread count, an empty mask must be a
// no-op, and a random sparse mask must equal a scalar per-coordinate
// reference walk that reads z at the same global counters.
// ---------------------------------------------------------------------

/// Which masked kernel a property case exercises.
const MASKED_KERNELS: [&str; 6] =
    ["axpy_z", "perturb_into", "sgd_update", "multi_sgd_update", "fzoo_update", "multi_axpy_z"];

/// Run one masked kernel over `idxs` and its dense counterpart over the
/// whole buffer, returning (masked_out, dense_out) from the same `init`.
#[allow(clippy::too_many_arguments)]
fn run_masked_and_dense(
    kernel: &str,
    eng: &mezo::zkernel::ZEngine,
    init: &[f32],
    idxs: &[u32],
    offset: u64,
    zs: &[(GaussianStream, f32)],
    lr: f32,
    wd: f32,
) -> (Vec<f32>, Vec<f32>) {
    let (stream, g) = zs[0];
    let mut masked = init.to_vec();
    let mut dense = init.to_vec();
    match kernel {
        "axpy_z" => {
            eng.axpy_z_masked(stream, offset, idxs, &mut masked, g);
            eng.axpy_z(stream, offset, &mut dense, g);
        }
        "perturb_into" => {
            // staging semantics: out starts mirroring θ, masked coords get
            // θ + s·z; the dense kernel rewrites every coordinate
            eng.perturb_into_masked(stream, offset, idxs, init, g, &mut masked);
            eng.perturb_into(stream, offset, init, g, &mut dense);
        }
        "sgd_update" => {
            eng.sgd_update_masked(stream, offset, idxs, &mut masked, lr, g, wd);
            eng.sgd_update(stream, offset, &mut dense, lr, g, wd);
        }
        "multi_sgd_update" => {
            eng.multi_sgd_update_masked(zs, offset, idxs, &mut masked, lr, wd);
            eng.multi_sgd_update(zs, offset, &mut dense, lr, wd);
        }
        "fzoo_update" => {
            eng.fzoo_update_masked(zs, offset, idxs, &mut masked, lr, wd);
            eng.fzoo_update(zs, offset, &mut dense, lr, wd);
        }
        "multi_axpy_z" => {
            eng.multi_axpy_z_masked(zs, offset, idxs, &mut masked);
            eng.multi_axpy_z(zs, offset, &mut dense);
        }
        _ => unreachable!(),
    }
    (masked, dense)
}

#[test]
fn prop_masked_kernels_with_full_mask_equal_dense_bitwise() {
    // satellite 1a: full mask == dense kernel, to_bits-identical, threads
    // 1/2/8, block-unaligned lengths and nonzero offsets
    forall(
        40,
        31,
        |rng| {
            let len = match rng.below(4) {
                0 => rng.below(300) + 1,           // sub-block
                1 => 256 + rng.below(5),           // straddles one block
                2 => rng.below(3000) + 257,        // several blocks, unaligned
                _ => 70_000 + rng.below(7),        // threads actually spawn
            };
            let kernel = *rng.choice(&MASKED_KERNELS);
            let n_seeds = rng.below(3) + 1;
            (kernel, len, rng.next_u64(), rng.below(1000) as u64, n_seeds)
        },
        |&(kernel, len, seed, offset, n_seeds)| {
            let mut init_rng = Pcg::new(seed ^ 0x11);
            let init: Vec<f32> = (0..len).map(|_| init_rng.normal_f32(0.0, 1.0)).collect();
            let zs: Vec<(GaussianStream, f32)> = (0..n_seeds)
                .map(|k| (GaussianStream::new(seed ^ k as u64), 0.3 - 0.2 * k as f32))
                .collect();
            let full: Vec<u32> = (0..len as u32).collect();
            for threads in [1usize, 2, 8] {
                let eng = mezo::zkernel::ZEngine::with_threads(threads);
                let (masked, dense) =
                    run_masked_and_dense(kernel, &eng, &init, &full, offset, &zs, 1e-2, 1e-4);
                for (j, (a, b)) in masked.iter().zip(&dense).enumerate() {
                    if a.to_bits() != b.to_bits() {
                        return Err(format!(
                            "{} t={} len={} coord {}: {} vs {}",
                            kernel, threads, len, j, a, b
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_masked_kernels_with_empty_mask_are_noops() {
    forall(
        20,
        32,
        |rng| {
            (*rng.choice(&MASKED_KERNELS), rng.below(2000) + 1, rng.next_u64())
        },
        |&(kernel, len, seed)| {
            let mut init_rng = Pcg::new(seed ^ 0x22);
            let init: Vec<f32> = (0..len).map(|_| init_rng.normal_f32(0.0, 1.0)).collect();
            let zs = vec![(GaussianStream::new(seed), 0.7f32)];
            let eng = mezo::zkernel::ZEngine::with_threads(4);
            let (masked, _) = run_masked_and_dense(kernel, &eng, &init, &[], 5, &zs, 1e-2, 1e-4);
            for (j, (a, b)) in masked.iter().zip(&init).enumerate() {
                if a.to_bits() != b.to_bits() {
                    return Err(format!("{} len={} coord {} changed: {} vs {}", kernel, len, j, a, b));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_masked_kernels_match_scalar_reference_on_random_masks() {
    // satellite 1b: a random sparse mask equals a scalar per-coordinate
    // walk reading z(offset + idx) — and untouched coordinates stay put
    forall(
        40,
        33,
        |rng| {
            let len = rng.below(3000) + 10;
            let density = [0.01, 0.1, 0.5][rng.below(3)];
            let kernel = *rng.choice(&MASKED_KERNELS);
            let n_seeds = rng.below(3) + 1;
            (kernel, len, density, rng.next_u64(), n_seeds)
        },
        |&(kernel, len, density, seed, n_seeds)| {
            let mut rng = Pcg::new(seed ^ 0x33);
            let init: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let idxs: Vec<u32> =
                (0..len as u32).filter(|_| rng.next_f64() < density).collect();
            let zs: Vec<(GaussianStream, f32)> = (0..n_seeds)
                .map(|k| (GaussianStream::new(seed ^ (0xA0 + k as u64)), 0.4 - 0.25 * k as f32))
                .collect();
            let (lr, wd, offset) = (1e-2f32, 1e-4f32, 17u64);
            // scalar reference walk over the masked coordinates only
            let mut reference = init.clone();
            let n_f = zs.len() as f32;
            for &i in &idxs {
                let c = i as usize;
                let zi = |s: &GaussianStream| s.z(offset + i as u64);
                match kernel {
                    "axpy_z" => reference[c] += zs[0].1 * zi(&zs[0].0),
                    "perturb_into" => reference[c] = init[c] + zs[0].1 * zi(&zs[0].0),
                    "sgd_update" => {
                        let z = zi(&zs[0].0);
                        let cur = reference[c];
                        reference[c] = cur - lr * (zs[0].1 * z + wd * cur);
                    }
                    "multi_sgd_update" => {
                        for &(s, g) in &zs {
                            let z = zi(&s);
                            let cur = reference[c];
                            reference[c] = cur - lr * (g * z + wd * cur);
                        }
                    }
                    "fzoo_update" => {
                        let mut g = 0.0f32;
                        for &(s, pg) in &zs {
                            g += pg * zi(&s);
                        }
                        let cur = reference[c];
                        reference[c] = cur - lr * (g / n_f + wd * cur);
                    }
                    "multi_axpy_z" => {
                        for &(s, sc) in &zs {
                            reference[c] += sc * zi(&s);
                        }
                    }
                    _ => unreachable!(),
                }
            }
            for threads in [1usize, 2, 8] {
                let eng = mezo::zkernel::ZEngine::with_threads(threads);
                let (masked, _) =
                    run_masked_and_dense(kernel, &eng, &init, &idxs, offset, &zs, lr, wd);
                for (j, (a, b)) in masked.iter().zip(&reference).enumerate() {
                    if a.to_bits() != b.to_bits() {
                        return Err(format!(
                            "{} t={} len={} density={} coord {}: {} vs {}",
                            kernel, threads, len, density, j, a, b
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_masked_fzoo_n1_full_mask_without_variance_norm_is_one_sided_spsa() {
    // satellite 2: the PR-2 pin extended to the masked path — FZOO under a
    // FULL mask with a single seed and no variance normalization is still
    // EXACTLY the one-sided SPSA update, bit for bit
    use mezo::optim::fzoo::{Fzoo, FzooConfig};
    use mezo::zkernel::{SparseMask, ZEngine};

    fn quad(p: &ParamStore) -> f32 {
        p.data.iter().flatten().map(|&x| (x - 1.0) * (x - 1.0)).sum()
    }

    forall(
        15,
        34,
        |rng| {
            (
                rng.next_u64(),
                rng.below(300) + 1,
                rng.below(300) + 1,
                1e-3 + rng.next_f32() * 1e-2, // lr
                1e-3 + rng.next_f32() * 9e-3, // eps
                rng.next_f32() * 1e-3,        // wd
            )
        },
        |&(master, d1, d2, lr, eps, wd)| {
            let specs = vec![
                TensorDesc { name: "a".into(), shape: vec![d1], dtype: "f32".into() },
                TensorDesc { name: "b".into(), shape: vec![d2], dtype: "f32".into() },
            ];
            let mut p = ParamStore::from_specs(specs);
            p.init(master);
            let p0 = p.clone();

            let cfg = FzooConfig {
                lr,
                eps,
                weight_decay: wd,
                n: 1,
                variance_norm: false,
                ..Default::default()
            };
            let mut opt = Fzoo::new(cfg, vec![0, 1], master ^ 0x5EED);
            opt.mask = Some(SparseMask::full(&p, &[0, 1]));
            let info = opt.step(&mut p, |p| Ok(quad(p))).unwrap();

            // reference: the one-sided SPSA update, dense kernels
            let engine = ZEngine::default();
            let seed = Pcg::new(master ^ 0x5EED).next_u64();
            let stream = GaussianStream::new(seed);
            let mut staged = p0.clone();
            for ti in [0usize, 1] {
                engine.perturb_into(stream, p0.offsets[ti], &p0.data[ti], eps, &mut staged.data[ti]);
            }
            let g = (quad(&staged) - quad(&p0)) / eps;
            let mut want = p0.clone();
            for ti in [0usize, 1] {
                engine.sgd_update(stream, want.offsets[ti], &mut want.data[ti], lr, g, wd);
            }

            ensure(info.seed == seed, "seed stream diverged")?;
            ensure(
                info.pgrad.to_bits() == g.to_bits(),
                format!("pgrad {} vs one-sided g {}", info.pgrad, g),
            )?;
            for (x, y) in p.data.iter().flatten().zip(want.data.iter().flatten()) {
                if x.to_bits() != y.to_bits() {
                    return Err(format!("param drifted: {} vs {}", x, y));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sparse_trajectory_replays_bitwise_from_seeds_and_digest() {
    // acceptance: a sparse FZOO/MeZO run replays from its logged seeds +
    // mask digest, bit-identically across thread counts and replay
    // flavors (sequential vs batched both equal a scalar reference walk)
    use mezo::optim::fzoo::{Fzoo, FzooConfig};
    use mezo::optim::mezo::{MezoConfig, MezoSgd};
    use mezo::storage::Trajectory;
    use mezo::zkernel::{Sensitivity, SparseMask, ZEngine};

    fn quad(p: &ParamStore) -> f32 {
        p.data.iter().flatten().map(|&x| (x - 0.5) * (x - 0.5)).sum()
    }

    forall(
        10,
        35,
        |rng| {
            (
                rng.next_u64(),
                rng.below(400) + 50,
                rng.below(400) + 50,
                rng.below(2) == 0, // fzoo or mezo
                rng.below(3) + 1,  // seeds per step
            )
        },
        |&(master, d1, d2, use_fzoo, n)| {
            let specs = vec![
                TensorDesc { name: "a".into(), shape: vec![d1], dtype: "f32".into() },
                TensorDesc { name: "b".into(), shape: vec![d2], dtype: "f32".into() },
            ];
            let mk = || {
                let mut p = ParamStore::from_specs(specs.clone());
                p.init(master);
                p
            };
            let mut trained = mk();
            let k = ((d1 + d2) / 5).max(1);
            let mask = SparseMask::top_k(&trained, &[0, 1], k, Sensitivity::Magnitude)
                .map_err(|e| e.to_string())?;
            let names = vec!["a".to_string(), "b".to_string()];
            let traj = if use_fzoo {
                let cfg =
                    FzooConfig { lr: 1e-2, eps: 1e-3, n, variance_norm: false, ..Default::default() };
                let mut opt = Fzoo::new(cfg, vec![0, 1], master ^ 0xF);
                opt.mask = Some(mask.clone());
                for _ in 0..8 {
                    opt.step(&mut trained, |p| Ok(quad(p))).map_err(|e| e.to_string())?;
                }
                Trajectory::from_run(names, &opt.history).with_mask_digest(mask.digest())
            } else {
                let cfg = MezoConfig { lr: 1e-2, eps: 1e-3, n, ..Default::default() };
                let mut opt = MezoSgd::new(cfg, vec![0, 1], master ^ 0xF);
                opt.mask = Some(mask.clone());
                for _ in 0..8 {
                    opt.step(&mut trained, |p| Ok(quad(p))).map_err(|e| e.to_string())?;
                }
                Trajectory::from_run(names, &opt.history).with_mask_digest(mask.digest())
            };

            // scalar reference replay: θ[i] -= lr·pgrad·z(off + i) per
            // record, masked coordinates only
            let mut reference = mk();
            for r in &traj.records {
                let stream = GaussianStream::new(r.seed);
                for ti in [0usize, 1] {
                    let off = reference.offsets[ti];
                    for &i in mask.indices(ti) {
                        reference.data[ti][i as usize] +=
                            -(r.lr * r.pgrad) * stream.z(off + i as u64);
                    }
                }
            }
            for threads in [1usize, 2, 8] {
                let eng = ZEngine::with_threads(threads);
                let mut seq = mk();
                traj.replay_masked_with(&eng, &mut seq, &mask).map_err(|e| e.to_string())?;
                for (a, b) in seq.data.iter().flatten().zip(reference.data.iter().flatten()) {
                    ensure(
                        a.to_bits() == b.to_bits(),
                        format!("t={}: sequential replay vs scalar reference: {} vs {}", threads, a, b),
                    )?;
                }
                // the batched replay applies seeds per coordinate in
                // record order, so ANY batch size equals the sequential
                // walk bit for bit
                for batch in [1usize, n] {
                    let mut bat = mk();
                    traj.replay_batched_masked_with(&eng, &mut bat, &mask, batch)
                        .map_err(|e| e.to_string())?;
                    for (x, y) in bat.data.iter().flatten().zip(seq.data.iter().flatten()) {
                        ensure(
                            x.to_bits() == y.to_bits(),
                            format!("t={} batch={}: batched replay diverged", threads, batch),
                        )?;
                    }
                }
            }
            // the sparse log round-trips through disk with its digest
            let path = std::env::temp_dir().join(format!("mezo_prop_sparse_{}.bin", master));
            traj.save(&path).map_err(|e| e.to_string())?;
            let back = Trajectory::load(&path).map_err(|e| e.to_string())?;
            std::fs::remove_file(&path).ok();
            ensure(back == traj, "sparse trajectory roundtrip")?;
            ensure(back.mask_digest == Some(mask.digest()), "digest survived")?;
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// Persistent worker-pool dispatch (ISSUE 4). The pool path must be a pure
// scheduling change: every dense and masked kernel, and every optimizer
// trajectory built on them, produces bit-identical results on the pool
// dispatcher (ZEngine::with_threads) and the retained per-call
// std::thread::scope dispatcher (ZEngine::with_threads_scoped), at
// thread counts 1/2/8.
// ---------------------------------------------------------------------

#[test]
fn prop_pool_dispatch_is_bit_identical_to_scope_dispatch_for_every_kernel() {
    use mezo::zkernel::{AdamParams, ZEngine};

    const KERNELS: [&str; 17] = [
        "fill_z",
        "axpy_z",
        "perturb_into",
        "sgd_update",
        "multi_sgd_update",
        "fzoo_update",
        "multi_axpy_z",
        "momentum_update",
        "adam_update",
        "ema_z",
        "project_rows",
        "axpy_z_masked",
        "perturb_into_masked",
        "sgd_update_masked",
        "multi_sgd_update_masked",
        "fzoo_update_masked",
        "multi_axpy_z_masked",
    ];

    /// Run one kernel on the given engine; returns every output buffer.
    #[allow(clippy::too_many_arguments)]
    fn run(
        kernel: &str,
        eng: &ZEngine,
        init: &[f32],
        aux: &[f32],
        aux2: &[f32],
        idxs: &[u32],
        zs: &[(GaussianStream, f32)],
        offset: u64,
    ) -> Vec<Vec<f32>> {
        let (stream, g) = zs[0];
        let (lr, wd) = (1e-2f32, 1e-4f32);
        let mut theta = init.to_vec();
        match kernel {
            "fill_z" => {
                let mut out = vec![0.0; init.len()];
                eng.fill_z(stream, offset, &mut out);
                vec![out]
            }
            "axpy_z" => {
                eng.axpy_z(stream, offset, &mut theta, g);
                vec![theta]
            }
            "perturb_into" => {
                let mut out = vec![0.0; init.len()];
                eng.perturb_into(stream, offset, init, g, &mut out);
                vec![out]
            }
            "sgd_update" => {
                eng.sgd_update(stream, offset, &mut theta, lr, g, wd);
                vec![theta]
            }
            "multi_sgd_update" => {
                eng.multi_sgd_update(zs, offset, &mut theta, lr, wd);
                vec![theta]
            }
            "fzoo_update" => {
                eng.fzoo_update(zs, offset, &mut theta, lr, wd);
                vec![theta]
            }
            "multi_axpy_z" => {
                eng.multi_axpy_z(zs, offset, &mut theta);
                vec![theta]
            }
            "momentum_update" => {
                let mut m = aux.to_vec();
                eng.momentum_update(zs, offset, &mut theta, &mut m, lr, wd, 0.9, zs.len() as f32);
                vec![theta, m]
            }
            "adam_update" => {
                let mut m = aux.to_vec();
                let mut v = aux2.to_vec();
                let p = AdamParams {
                    lr,
                    wd,
                    beta1: 0.9,
                    beta2: 0.999,
                    eps: 1e-8,
                    t: 3.0,
                    n: zs.len() as f32,
                };
                eng.adam_update(zs, offset, &mut theta, &mut m, &mut v, p);
                vec![theta, m, v]
            }
            "ema_z" => {
                let mut m = aux.to_vec();
                eng.ema_z(stream, offset, &mut m, g, 0.9, true);
                vec![m]
            }
            "project_rows" => {
                let d_low = 48usize;
                let mut out = vec![0.0; init.len()];
                eng.project_rows(stream, d_low, &aux[..d_low], init, 0.125, &mut out);
                vec![out]
            }
            "axpy_z_masked" => {
                eng.axpy_z_masked(stream, offset, idxs, &mut theta, g);
                vec![theta]
            }
            "perturb_into_masked" => {
                let mut out = init.to_vec();
                eng.perturb_into_masked(stream, offset, idxs, init, g, &mut out);
                vec![out]
            }
            "sgd_update_masked" => {
                eng.sgd_update_masked(stream, offset, idxs, &mut theta, lr, g, wd);
                vec![theta]
            }
            "multi_sgd_update_masked" => {
                eng.multi_sgd_update_masked(zs, offset, idxs, &mut theta, lr, wd);
                vec![theta]
            }
            "fzoo_update_masked" => {
                eng.fzoo_update_masked(zs, offset, idxs, &mut theta, lr, wd);
                vec![theta]
            }
            "multi_axpy_z_masked" => {
                eng.multi_axpy_z_masked(zs, offset, idxs, &mut theta);
                vec![theta]
            }
            _ => unreachable!(),
        }
    }

    forall(
        8,
        36,
        |rng| {
            let len = match rng.below(3) {
                0 => rng.below(300) + 60,      // sub-block to small
                1 => 3 * 256 + rng.below(7),   // several blocks, unaligned
                _ => 70_000 + rng.below(7),    // threads actually fan out
            };
            (len, rng.next_u64(), rng.below(500) as u64, rng.below(3) + 1)
        },
        |&(len, seed, offset, n_seeds)| {
            let mut rng = Pcg::new(seed ^ 0x44);
            let init: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let aux: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let aux2: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 0.5).abs()).collect();
            let idxs: Vec<u32> = (0..len as u32).filter(|_| rng.next_f64() < 0.2).collect();
            let zs: Vec<(GaussianStream, f32)> = (0..n_seeds)
                .map(|k| (GaussianStream::new(seed ^ (0xB0 + k as u64)), 0.35 - 0.3 * k as f32))
                .collect();
            for kernel in KERNELS {
                for threads in [1usize, 2, 8] {
                    let pool_eng = ZEngine::with_threads(threads);
                    let scope_eng = ZEngine::with_threads_scoped(threads);
                    let pool = run(kernel, &pool_eng, &init, &aux, &aux2, &idxs, &zs, offset);
                    let scope = run(kernel, &scope_eng, &init, &aux, &aux2, &idxs, &zs, offset);
                    for (bi, (pb, sb)) in pool.iter().zip(&scope).enumerate() {
                        for (j, (a, b)) in pb.iter().zip(sb).enumerate() {
                            if a.to_bits() != b.to_bits() {
                                return Err(format!(
                                    "{} t={} len={} buf {} coord {}: pool {} vs scope {}",
                                    kernel, threads, len, bi, j, a, b
                                ));
                            }
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pool_optimizer_runs_match_scope_runs_and_replay_bitwise() {
    // satellite: pool-path trajectories replay bitwise against pre-pool
    // (scope-dispatched) seed logs — the run, its history, and every
    // replay flavor of the log are dispatch-invariant
    use mezo::optim::fzoo::{Fzoo, FzooConfig};
    use mezo::optim::mezo::{MezoConfig, MezoSgd};
    use mezo::zkernel::ZEngine;

    fn quad(p: &ParamStore) -> f32 {
        p.data.iter().flatten().map(|&x| (x - 0.7) * (x - 0.7)).sum()
    }

    forall(
        8,
        37,
        |rng| {
            (
                rng.next_u64(),
                rng.below(400) + 50,
                rng.below(400) + 50,
                rng.below(2) == 0, // fzoo or mezo
                rng.below(3) + 1,  // seeds per step
            )
        },
        |&(master, d1, d2, use_fzoo, n)| {
            let specs = vec![
                TensorDesc { name: "a".into(), shape: vec![d1], dtype: "f32".into() },
                TensorDesc { name: "b".into(), shape: vec![d2], dtype: "f32".into() },
            ];
            let mk = || {
                let mut p = ParamStore::from_specs(specs.clone());
                p.init(master);
                p
            };
            let run_with = |engine: ZEngine| -> (Vec<StepRecord>, Vec<Vec<f32>>) {
                let mut p = mk();
                if use_fzoo {
                    let cfg = FzooConfig { lr: 1e-2, eps: 1e-3, n, ..Default::default() };
                    let mut opt = Fzoo::new(cfg, vec![0, 1], master ^ 0x77);
                    opt.engine = engine;
                    for _ in 0..6 {
                        opt.step(&mut p, |p| Ok(quad(p))).unwrap();
                    }
                    (opt.history.clone(), p.data.clone())
                } else {
                    let cfg = MezoConfig { lr: 1e-2, eps: 1e-3, n, ..Default::default() };
                    let mut opt = MezoSgd::new(cfg, vec![0, 1], master ^ 0x77);
                    opt.engine = engine;
                    for _ in 0..6 {
                        opt.step(&mut p, |p| Ok(quad(p))).unwrap();
                    }
                    (opt.history.clone(), p.data.clone())
                }
            };
            // "pre-pool" run: the retained scope dispatch path
            let (scope_hist, scope_data) = run_with(ZEngine::with_threads_scoped(4));
            let (pool_hist, pool_data) = run_with(ZEngine::with_threads(4));
            ensure(scope_hist.len() == pool_hist.len(), "history length diverged")?;
            for (a, b) in scope_hist.iter().zip(&pool_hist) {
                ensure(a.seed == b.seed, "seed diverged")?;
                ensure(a.pgrad.to_bits() == b.pgrad.to_bits(), "pgrad diverged")?;
                ensure(a.lr.to_bits() == b.lr.to_bits(), "lr diverged")?;
            }
            for (x, y) in scope_data.iter().flatten().zip(pool_data.iter().flatten()) {
                ensure(x.to_bits() == y.to_bits(), "trained params diverged")?;
            }
            // the pre-pool seed log replays bitwise on the pool path, at
            // any thread count, sequentially and seed-batched
            let names = vec!["a".to_string(), "b".to_string()];
            let traj = Trajectory::from_run(names, &scope_hist);
            let mut reference = mk();
            traj.replay_with(&ZEngine::with_threads_scoped(4), &mut reference);
            for threads in [1usize, 2, 8] {
                let eng = ZEngine::with_threads(threads);
                let mut seq = mk();
                traj.replay_with(&eng, &mut seq);
                for (x, y) in seq.data.iter().flatten().zip(reference.data.iter().flatten()) {
                    ensure(
                        x.to_bits() == y.to_bits(),
                        format!("t={}: pool replay diverged from scope replay", threads),
                    )?;
                }
                let mut bat = mk();
                traj.replay_batched_with(&eng, &mut bat, n).map_err(|e| e.to_string())?;
                for (x, y) in bat.data.iter().flatten().zip(seq.data.iter().flatten()) {
                    ensure(
                        x.to_bits() == y.to_bits(),
                        format!("t={}: pool batched replay diverged", threads),
                    )?;
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// Sharded parameter store (ISSUE 5). A ShardPlan partitions the global
// coordinate space; every shard-scoped pass reads z at the same global
// counters as the dense kernels, so shard-by-shard execution must be
// bitwise the dense run: shard kernels over a partition equal the dense
// kernel, gathering a ShardedStore after K-way sharded replay equals
// dense Trajectory::replay, and shard-scoped optimizer steps equal dense
// steps — for shard counts 1/2/4 crossed with threads 1/2/8 (and the
// whole file re-runs under MEZO_THREADS=1/2/8 via scripts/verify.sh).
// ---------------------------------------------------------------------

#[test]
fn prop_shard_kernel_partitions_equal_the_dense_kernels_bitwise() {
    forall(
        20,
        41,
        |rng| {
            let len = match rng.below(3) {
                0 => rng.below(300) + 2,        // sub-block
                1 => rng.below(3000) + 257,     // several blocks, unaligned
                _ => 70_000 + rng.below(7),     // threads actually fan out
            };
            let n_cuts = rng.below(4); // 0..=3 interior cuts
            let cuts: Vec<usize> = (0..n_cuts).map(|_| rng.below(len)).collect();
            (len, cuts, rng.next_u64(), rng.below(900) as u64, rng.below(3) + 1)
        },
        |(len, cuts, seed, offset, n_seeds)| {
            let (len, offset) = (*len, *offset);
            let mut bounds = vec![0usize, len];
            bounds.extend(cuts.iter().copied());
            bounds.sort_unstable();
            let mut init_rng = Pcg::new(seed ^ 0x55);
            let init: Vec<f32> = (0..len).map(|_| init_rng.normal_f32(0.0, 1.0)).collect();
            let zs: Vec<(GaussianStream, f32)> = (0..*n_seeds)
                .map(|k| (GaussianStream::new(seed ^ (0xC0 + k as u64)), 0.3 - 0.2 * k as f32))
                .collect();
            let (stream, g) = zs[0];
            let (lr, wd, s) = (1e-2f32, 1e-4f32, 2e-3f32);
            for threads in [1usize, 2, 8] {
                let eng = mezo::zkernel::ZEngine::with_threads(threads);
                // dense references
                let mut d_axpy = init.clone();
                eng.axpy_z(stream, offset, &mut d_axpy, s);
                let mut d_sgd = init.clone();
                eng.sgd_update(stream, offset, &mut d_sgd, lr, g, wd);
                let mut d_msgd = init.clone();
                eng.multi_sgd_update(&zs, offset, &mut d_msgd, lr, wd);
                let mut d_fzoo = init.clone();
                eng.fzoo_update(&zs, offset, &mut d_fzoo, lr, wd);
                let mut d_maxpy = init.clone();
                eng.multi_axpy_z(&zs, offset, &mut d_maxpy);
                let mut d_pert = vec![0.0f32; len];
                eng.perturb_into(stream, offset, &init, s, &mut d_pert);
                // the same passes shard by shard over the random partition
                let mut s_axpy = init.clone();
                let mut s_sgd = init.clone();
                let mut s_msgd = init.clone();
                let mut s_fzoo = init.clone();
                let mut s_maxpy = init.clone();
                let mut s_pert = vec![0.0f32; len];
                for w in bounds.windows(2) {
                    let (lo, hi) = (w[0], w[1]);
                    eng.axpy_z_shard(stream, offset, lo, hi, &mut s_axpy, s);
                    eng.sgd_update_shard(stream, offset, lo, hi, &mut s_sgd, lr, g, wd);
                    eng.multi_sgd_update_shard(&zs, offset, lo, hi, &mut s_msgd, lr, wd);
                    eng.fzoo_update_shard(&zs, offset, lo, hi, &mut s_fzoo, lr, wd);
                    eng.multi_axpy_z_shard(&zs, offset, lo, hi, &mut s_maxpy);
                    eng.perturb_into_shard(stream, offset, lo, hi, &init, s, &mut s_pert);
                }
                for (name, got, want) in [
                    ("axpy_z", &s_axpy, &d_axpy),
                    ("sgd_update", &s_sgd, &d_sgd),
                    ("multi_sgd_update", &s_msgd, &d_msgd),
                    ("fzoo_update", &s_fzoo, &d_fzoo),
                    ("multi_axpy_z", &s_maxpy, &d_maxpy),
                    ("perturb_into", &s_pert, &d_pert),
                ] {
                    for (j, (a, b)) in got.iter().zip(want).enumerate() {
                        if a.to_bits() != b.to_bits() {
                            return Err(format!(
                                "{} t={} len={} cuts={:?} coord {}: {} vs {}",
                                name, threads, len, bounds, j, a, b
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sharded_replay_gathers_bitwise_to_dense_replay() {
    // the ISSUE 5 acceptance: gather(K-way sharded replay) == dense
    // replay, to_bits, for shards 1/2/4 at threads 1/2/8, sequential and
    // seed-batched, with an MZT3 disk round-trip and a wrong-plan-digest
    // error path
    use mezo::shard::{ShardManifest, ShardPlan, ShardedStore};

    forall(
        8,
        42,
        |rng| {
            let d1 = if rng.below(4) == 0 { 70_000 + rng.below(7) } else { rng.below(400) + 50 };
            (rng.next_u64(), d1, rng.below(400) + 50, rng.below(3) + 1, rng.below(30) + 1)
        },
        |&(master, d1, d2, seeds_per_step, n_steps)| {
            let specs = vec![
                TensorDesc { name: "a".into(), shape: vec![d1], dtype: "f32".into() },
                TensorDesc { name: "b".into(), shape: vec![d2], dtype: "f32".into() },
            ];
            let mk = || {
                let mut p = ParamStore::from_specs(specs.clone());
                p.init(master);
                p
            };
            let mut traj = Trajectory::new(vec!["a".into(), "b".into()]);
            let mut rng = Pcg::new(master ^ 0x66);
            for _ in 0..n_steps * seeds_per_step {
                traj.records.push(StepRecord {
                    seed: rng.next_u64(),
                    pgrad: rng.normal() as f32,
                    lr: rng.next_f32() * 1e-2,
                });
            }
            let init = mk();
            let mut dense = mk();
            traj.replay_with(&mezo::zkernel::ZEngine::with_threads(2), &mut dense);
            for k in [1usize, 2, 4] {
                let plan = ShardPlan::new(&init, k).map_err(|e| e.to_string())?;
                // the manifest round-trips through disk before guarding
                let path = std::env::temp_dir()
                    .join(format!("mezo_prop_mzt3_{}_{}.bin", master, k));
                plan.manifest().save(&path).map_err(|e| e.to_string())?;
                let manifest = ShardManifest::load(&path).map_err(|e| e.to_string())?;
                std::fs::remove_file(&path).ok();
                ensure(manifest == plan.manifest(), "MZT3 roundtrip")?;
                for threads in [1usize, 2, 8] {
                    let eng = mezo::zkernel::ZEngine::with_threads(threads);
                    for batched in [false, true] {
                        let mut sharded =
                            ShardedStore::scatter(&plan, &init).map_err(|e| e.to_string())?;
                        if batched {
                            traj.replay_sharded_batched_with(
                                &eng,
                                &mut sharded,
                                &manifest,
                                seeds_per_step,
                            )
                            .map_err(|e| e.to_string())?;
                        } else {
                            traj.replay_sharded_with(&eng, &mut sharded, &manifest)
                                .map_err(|e| e.to_string())?;
                        }
                        let mut gathered = mk();
                        sharded.gather_into(&mut gathered).map_err(|e| e.to_string())?;
                        for (a, b) in
                            dense.data.iter().flatten().zip(gathered.data.iter().flatten())
                        {
                            if a.to_bits() != b.to_bits() {
                                return Err(format!(
                                    "k={} t={} batched={}: {} vs {}",
                                    k, threads, batched, a, b
                                ));
                            }
                        }
                    }
                }
                // wrong-plan digest: a manifest from a different partition
                // must refuse loudly
                let other = ShardPlan::new(&init, k + 1).map_err(|e| e.to_string())?;
                let mut sharded =
                    ShardedStore::scatter(&plan, &init).map_err(|e| e.to_string())?;
                let err = traj
                    .replay_sharded(&mut sharded, &other.manifest())
                    .expect_err("mismatched plan must not replay");
                ensure(
                    err.to_string().contains("plan digest"),
                    format!("unexpected error: {}", err),
                )?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sharded_stepping_is_bitwise_dense_stepping() {
    // shard-scoped optimizer steps (MezoSgd and Fzoo) equal the dense
    // steps bit for bit: same history, same final θ, for shards 1/2/4 at
    // threads 1/2/8
    use mezo::optim::fzoo::{Fzoo, FzooConfig};
    use mezo::optim::mezo::{MezoConfig, MezoSgd};
    use mezo::shard::ShardPlan;
    use mezo::zkernel::ZEngine;

    fn quad(p: &ParamStore) -> f32 {
        p.data.iter().flatten().map(|&x| (x - 0.4) * (x - 0.4)).sum()
    }

    forall(
        4,
        43,
        |rng| {
            (
                rng.next_u64(),
                rng.below(400) + 50,
                rng.below(400) + 50,
                rng.below(2) == 0, // fzoo or mezo
                rng.below(3) + 1,  // seeds per step
            )
        },
        |&(master, d1, d2, use_fzoo, n)| {
            let specs = vec![
                TensorDesc { name: "a".into(), shape: vec![d1], dtype: "f32".into() },
                TensorDesc { name: "b".into(), shape: vec![d2], dtype: "f32".into() },
            ];
            let mk = || {
                let mut p = ParamStore::from_specs(specs.clone());
                p.init(master);
                p
            };
            let run = |engine: ZEngine,
                       shard: Option<ShardPlan>|
             -> Result<(Vec<StepRecord>, Vec<Vec<f32>>), String> {
                let mut p = mk();
                if use_fzoo {
                    let cfg = FzooConfig {
                        lr: 1e-2,
                        eps: 1e-3,
                        weight_decay: 1e-4,
                        n,
                        ..Default::default()
                    };
                    let mut opt = Fzoo::new(cfg, vec![0, 1], master ^ 0x88);
                    opt.engine = engine;
                    opt.shard = shard;
                    for _ in 0..5 {
                        opt.step(&mut p, |p| Ok(quad(p))).map_err(|e| e.to_string())?;
                    }
                    Ok((opt.history.clone(), p.data.clone()))
                } else {
                    let cfg = MezoConfig {
                        lr: 1e-2,
                        eps: 1e-3,
                        weight_decay: 1e-4,
                        n,
                        ..Default::default()
                    };
                    let mut opt = MezoSgd::new(cfg, vec![0, 1], master ^ 0x88);
                    opt.engine = engine;
                    opt.shard = shard;
                    for _ in 0..5 {
                        opt.step(&mut p, |p| Ok(quad(p))).map_err(|e| e.to_string())?;
                    }
                    Ok((opt.history.clone(), p.data.clone()))
                }
            };
            let (dense_hist, dense_data) = run(ZEngine::with_threads(2), None)?;
            let init = mk();
            for k in [1usize, 2, 4] {
                let plan = ShardPlan::new(&init, k).map_err(|e| e.to_string())?;
                for threads in [1usize, 2, 8] {
                    let (hist, data) = run(ZEngine::with_threads(threads), Some(plan.clone()))?;
                    ensure(hist.len() == dense_hist.len(), "history length diverged")?;
                    for (a, b) in dense_hist.iter().zip(&hist) {
                        ensure(a.seed == b.seed, format!("k={} t={}: seed", k, threads))?;
                        ensure(
                            a.pgrad.to_bits() == b.pgrad.to_bits(),
                            format!("k={} t={}: pgrad", k, threads),
                        )?;
                        ensure(
                            a.lr.to_bits() == b.lr.to_bits(),
                            format!("k={} t={}: lr", k, threads),
                        )?;
                    }
                    for (x, y) in dense_data.iter().flatten().zip(data.iter().flatten()) {
                        ensure(
                            x.to_bits() == y.to_bits(),
                            format!("k={} t={}: {} vs {}", k, threads, x, y),
                        )?;
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// Explicit SIMD tiers (ISSUE 6). The runtime-dispatched AVX2/AVX-512/NEON
// block bodies are a pure instruction-selection change: per coordinate
// they perform the same IEEE single-operations in the same order as the
// scalar tier, so EVERY runnable tier must equal the scalar tier to the
// bit — for every dense, masked, and shard entry point, at threads 1/2/8,
// on lengths that are not a multiple of any lane width × 8 (so both the
// vector loop and every remainder size are exercised).
// ---------------------------------------------------------------------

#[test]
fn prop_every_simd_tier_is_bit_identical_to_scalar_for_every_kernel() {
    use mezo::zkernel::{AdamParams, Tier, ZEngine};

    const SIMD_KERNELS: [&str; 23] = [
        "fill_z",
        "axpy_z",
        "perturb_into",
        "sgd_update",
        "multi_sgd_update",
        "fzoo_update",
        "multi_axpy_z",
        "momentum_update",
        "adam_update",
        "ema_z",
        "project_rows",
        "axpy_z_masked",
        "perturb_into_masked",
        "sgd_update_masked",
        "multi_sgd_update_masked",
        "fzoo_update_masked",
        "multi_axpy_z_masked",
        "axpy_z_shard",
        "perturb_into_shard",
        "sgd_update_shard",
        "multi_sgd_update_shard",
        "fzoo_update_shard",
        "multi_axpy_z_shard",
    ];

    /// Run one kernel on the given engine; returns every output buffer.
    /// Shard entry points split the buffer at `cut` and run both halves.
    #[allow(clippy::too_many_arguments)]
    fn run(
        kernel: &str,
        eng: &ZEngine,
        init: &[f32],
        aux: &[f32],
        aux2: &[f32],
        idxs: &[u32],
        zs: &[(GaussianStream, f32)],
        offset: u64,
        cut: usize,
    ) -> Vec<Vec<f32>> {
        let (stream, g) = zs[0];
        let (lr, wd) = (1e-2f32, 1e-4f32);
        let len = init.len();
        let mut theta = init.to_vec();
        match kernel {
            "fill_z" => {
                let mut out = vec![0.0; len];
                eng.fill_z(stream, offset, &mut out);
                vec![out]
            }
            "axpy_z" => {
                eng.axpy_z(stream, offset, &mut theta, g);
                vec![theta]
            }
            "perturb_into" => {
                let mut out = vec![0.0; len];
                eng.perturb_into(stream, offset, init, g, &mut out);
                vec![out]
            }
            "sgd_update" => {
                eng.sgd_update(stream, offset, &mut theta, lr, g, wd);
                vec![theta]
            }
            "multi_sgd_update" => {
                eng.multi_sgd_update(zs, offset, &mut theta, lr, wd);
                vec![theta]
            }
            "fzoo_update" => {
                eng.fzoo_update(zs, offset, &mut theta, lr, wd);
                vec![theta]
            }
            "multi_axpy_z" => {
                eng.multi_axpy_z(zs, offset, &mut theta);
                vec![theta]
            }
            "momentum_update" => {
                let mut m = aux.to_vec();
                eng.momentum_update(zs, offset, &mut theta, &mut m, lr, wd, 0.9, zs.len() as f32);
                vec![theta, m]
            }
            "adam_update" => {
                let mut m = aux.to_vec();
                let mut v = aux2.to_vec();
                let p = AdamParams {
                    lr,
                    wd,
                    beta1: 0.9,
                    beta2: 0.999,
                    eps: 1e-8,
                    t: 3.0,
                    n: zs.len() as f32,
                };
                eng.adam_update(zs, offset, &mut theta, &mut m, &mut v, p);
                vec![theta, m, v]
            }
            "ema_z" => {
                let mut ma = aux.to_vec();
                eng.ema_z(stream, offset, &mut ma, g, 0.9, true);
                let mut ms = aux.to_vec();
                eng.ema_z(stream, offset, &mut ms, g, 0.9, false);
                vec![ma, ms]
            }
            "project_rows" => {
                let d_low = 48usize;
                let mut out = vec![0.0; len];
                eng.project_rows(stream, d_low, &aux[..d_low], init, 0.125, &mut out);
                vec![out]
            }
            "axpy_z_masked" => {
                eng.axpy_z_masked(stream, offset, idxs, &mut theta, g);
                vec![theta]
            }
            "perturb_into_masked" => {
                let mut out = init.to_vec();
                eng.perturb_into_masked(stream, offset, idxs, init, g, &mut out);
                vec![out]
            }
            "sgd_update_masked" => {
                eng.sgd_update_masked(stream, offset, idxs, &mut theta, lr, g, wd);
                vec![theta]
            }
            "multi_sgd_update_masked" => {
                eng.multi_sgd_update_masked(zs, offset, idxs, &mut theta, lr, wd);
                vec![theta]
            }
            "fzoo_update_masked" => {
                eng.fzoo_update_masked(zs, offset, idxs, &mut theta, lr, wd);
                vec![theta]
            }
            "multi_axpy_z_masked" => {
                eng.multi_axpy_z_masked(zs, offset, idxs, &mut theta);
                vec![theta]
            }
            "axpy_z_shard" => {
                eng.axpy_z_shard(stream, offset, 0, cut, &mut theta, g);
                eng.axpy_z_shard(stream, offset, cut, len, &mut theta, g);
                vec![theta]
            }
            "perturb_into_shard" => {
                let mut out = vec![0.0; len];
                eng.perturb_into_shard(stream, offset, 0, cut, init, g, &mut out);
                eng.perturb_into_shard(stream, offset, cut, len, init, g, &mut out);
                vec![out]
            }
            "sgd_update_shard" => {
                eng.sgd_update_shard(stream, offset, 0, cut, &mut theta, lr, g, wd);
                eng.sgd_update_shard(stream, offset, cut, len, &mut theta, lr, g, wd);
                vec![theta]
            }
            "multi_sgd_update_shard" => {
                eng.multi_sgd_update_shard(zs, offset, 0, cut, &mut theta, lr, wd);
                eng.multi_sgd_update_shard(zs, offset, cut, len, &mut theta, lr, wd);
                vec![theta]
            }
            "fzoo_update_shard" => {
                eng.fzoo_update_shard(zs, offset, 0, cut, &mut theta, lr, wd);
                eng.fzoo_update_shard(zs, offset, cut, len, &mut theta, lr, wd);
                vec![theta]
            }
            "multi_axpy_z_shard" => {
                eng.multi_axpy_z_shard(zs, offset, 0, cut, &mut theta);
                eng.multi_axpy_z_shard(zs, offset, cut, len, &mut theta);
                vec![theta]
            }
            _ => unreachable!(),
        }
    }

    let tiers: Vec<Tier> =
        Tier::available().into_iter().filter(|&t| t != Tier::Scalar).collect();
    if tiers.is_empty() {
        // scalar-only host (or pre-AVX-512 toolchain with no AVX2): the
        // dispatch layer degenerates to the scalar tier by construction
        return;
    }

    forall(
        6,
        61,
        |rng| {
            // 259, 4097, 70_003: not multiples of 4, 8, or 16 — every lane
            // width leaves a remainder, and the largest fans out threads
            let len = [259usize, 4097, 70_003][rng.below(3)];
            let cut = rng.below(len - 1) + 1;
            (len, cut, rng.next_u64(), rng.below(500) as u64, rng.below(3) + 1)
        },
        |&(len, cut, seed, offset, n_seeds)| {
            let mut rng = Pcg::new(seed ^ 0x99);
            let init: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let aux: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let aux2: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 0.5).abs()).collect();
            let idxs: Vec<u32> = (0..len as u32).filter(|_| rng.next_f64() < 0.2).collect();
            let zs: Vec<(GaussianStream, f32)> = (0..n_seeds)
                .map(|k| (GaussianStream::new(seed ^ (0xD0 + k as u64)), 0.35 - 0.3 * k as f32))
                .collect();
            for &tier in &tiers {
                for kernel in SIMD_KERNELS {
                    for threads in [1usize, 2, 8] {
                        let simd_eng = ZEngine::with_threads_simd(threads, tier);
                        let ref_eng = ZEngine::with_threads_simd(threads, Tier::Scalar);
                        let got =
                            run(kernel, &simd_eng, &init, &aux, &aux2, &idxs, &zs, offset, cut);
                        let want =
                            run(kernel, &ref_eng, &init, &aux, &aux2, &idxs, &zs, offset, cut);
                        for (bi, (gb, wb)) in got.iter().zip(&want).enumerate() {
                            for (j, (a, b)) in gb.iter().zip(wb).enumerate() {
                                if a.to_bits() != b.to_bits() {
                                    return Err(format!(
                                        "{} tier={} t={} len={} buf {} coord {}: {} vs {}",
                                        kernel, tier, threads, len, bi, j, a, b
                                    ));
                                }
                            }
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_fzoo_n1_without_variance_norm_is_the_one_sided_spsa_update() {
    // ISSUE 2 acceptance: with a single seed and variance normalization
    // off, an FZOO step must be EXACTLY (to_bits) the one-sided MeZO/SPSA
    // update θ −= lr·(g·z + wd·θ) with g = (L(θ+εz) − L(θ))/ε — the same
    // seed stream, the same staged evaluation, the same fused kernel
    // arithmetic.
    use mezo::optim::fzoo::{Fzoo, FzooConfig};
    use mezo::zkernel::ZEngine;

    fn quad(p: &ParamStore) -> f32 {
        p.data.iter().flatten().map(|&x| (x - 1.0) * (x - 1.0)).sum()
    }

    forall(
        25,
        21,
        |rng| {
            (
                rng.next_u64(),
                rng.below(300) + 1,
                rng.below(300) + 1,
                1e-3 + rng.next_f32() * 1e-2,        // lr
                1e-3 + rng.next_f32() * 9e-3,        // eps
                rng.next_f32() * 1e-3,               // wd
            )
        },
        |&(master, d1, d2, lr, eps, wd)| {
            let specs = vec![
                TensorDesc { name: "a".into(), shape: vec![d1], dtype: "f32".into() },
                TensorDesc { name: "b".into(), shape: vec![d2], dtype: "f32".into() },
            ];
            let mut p = ParamStore::from_specs(specs.clone());
            p.init(master);
            let p0 = p.clone();

            let cfg = FzooConfig {
                lr,
                eps,
                weight_decay: wd,
                n: 1,
                variance_norm: false,
                ..Default::default()
            };
            let mut opt = Fzoo::new(cfg, vec![0, 1], master ^ 0x5EED);
            let info = opt.step(&mut p, |p| Ok(quad(p))).unwrap();

            // reference: the one-sided SPSA update, from the public pieces
            let engine = ZEngine::default();
            let seed = Pcg::new(master ^ 0x5EED).next_u64();
            let stream = GaussianStream::new(seed);
            let mut staged = p0.clone();
            for ti in [0usize, 1] {
                engine.perturb_into(stream, p0.offsets[ti], &p0.data[ti], eps, &mut staged.data[ti]);
            }
            let g = (quad(&staged) - quad(&p0)) / eps;
            let mut want = p0.clone();
            for ti in [0usize, 1] {
                engine.sgd_update(stream, want.offsets[ti], &mut want.data[ti], lr, g, wd);
            }

            ensure(info.seed == seed, "seed stream diverged")?;
            ensure(
                info.pgrad.to_bits() == g.to_bits(),
                format!("pgrad {} vs one-sided g {}", info.pgrad, g),
            )?;
            ensure(opt.history.len() == 1, "one record per seed")?;
            ensure(opt.history[0].lr.to_bits() == lr.to_bits(), "raw lr must apply")?;
            for (x, y) in p.data.iter().flatten().zip(want.data.iter().flatten()) {
                if x.to_bits() != y.to_bits() {
                    return Err(format!("param drifted: {} vs {}", x, y));
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// MZW1 wire protocol (wire::frame): the adversarial surface. Decoding is
// total — arbitrary bytes, truncations and bit flips must come back as
// typed WireErrors, never panics — and a valid encode→decode roundtrip
// is byte-identical for every frame kind.
// ---------------------------------------------------------------------------

/// A random parameter-store geometry plus a plan over it — the input
/// shape every structured frame is built from.
fn gen_wire_plan(rng: &mut Pcg) -> mezo::shard::ShardPlan {
    let nt = rng.below(4) + 1;
    let specs = (0..nt)
        .map(|i| TensorDesc {
            name: format!("t{}", i),
            shape: vec![rng.below(400) + 1],
            dtype: "f32".into(),
        })
        .collect();
    let p = ParamStore::from_specs(specs);
    let k = rng.below(8) + 1;
    mezo::shard::ShardPlan::new(&p, k).expect("k >= 1")
}

/// One random message of a random kind, covering every frame kind the
/// protocol has (empty shards and empty buffers included).
fn gen_wire_msg(rng: &mut Pcg) -> mezo::wire::Msg {
    use mezo::wire::Msg;
    let plan = gen_wire_plan(rng);
    let mut log = Trajectory::new(
        (0..plan.n_tensors()).filter(|_| rng.below(2) == 0).map(|i| format!("t{}", i)).collect(),
    );
    log.records = (0..rng.below(6))
        .map(|_| StepRecord {
            seed: rng.next_u64(),
            pgrad: rng.next_f64() as f32 - 0.5,
            lr: 1e-3,
        })
        .collect();
    if rng.below(4) == 0 {
        log = log.with_mask_digest(rng.next_u64());
    }
    let k = rng.below(plan.n_shards());
    let segments: Vec<Vec<f32>> = plan
        .shard(k)
        .segments
        .iter()
        .map(|seg| (0..seg.len()).map(|_| rng.next_f64() as f32).collect())
        .collect();
    match rng.below(13) {
        0 => Msg::Hello { node: rng.next_u64() as u32 },
        1 => Msg::Ack,
        2 => Msg::Nack { message: format!("refused #{} — ünïcode ok", rng.below(100)) },
        3 => Msg::Plan(Box::new(plan)),
        4 => Msg::Manifest(plan.manifest()),
        5 => Msg::Log(Box::new(log)),
        6 => Msg::LoadShard {
            shard: k as u32,
            trainable: log.trainable.clone(),
            segments,
            plan: Box::new(plan),
        },
        7 => Msg::Perturb {
            plan_digest: plan.digest(),
            seed: rng.next_u64(),
            scale: rng.next_f64() as f32,
        },
        8 => Msg::Update {
            plan_digest: plan.digest(),
            zs: (0..rng.below(5)).map(|_| (rng.next_u64(), rng.next_f64() as f32)).collect(),
            lr: 1e-3,
            wd: 0.1,
        },
        9 => Msg::Replay {
            plan_digest: plan.digest(),
            log: Box::new(log),
            seeds_per_step: rng.below(4) as u32,
        },
        10 => Msg::FetchShard { plan_digest: plan.digest() },
        11 => Msg::ShardSlice {
            plan_digest: plan.digest(),
            shard: k as u32,
            shard_digest: plan.shard_digest(k),
            segments,
        },
        _ => Msg::Shutdown,
    }
}

#[test]
fn prop_wire_every_kind_roundtrips_byte_identically() {
    use mezo::wire::Msg;
    forall(
        300,
        71,
        gen_wire_msg,
        |msg| {
            let bytes = msg.encode();
            let (back, used) =
                Msg::decode(&bytes).map_err(|e| format!("{} failed: {}", msg.kind_name(), e))?;
            ensure(used == bytes.len(), "whole frame consumed")?;
            ensure(&back == msg, format!("{}: value roundtrip", msg.kind_name()))?;
            ensure(back.encode() == bytes, format!("{}: byte roundtrip", msg.kind_name()))
        },
    );
}

#[test]
fn prop_wire_arbitrary_bytes_never_panic() {
    use mezo::wire::Msg;
    forall(
        500,
        72,
        |rng| {
            let n = rng.below(200);
            (0..n).map(|_| rng.next_u64() as u8).collect::<Vec<u8>>()
        },
        |bytes| {
            // total decoding: any outcome but a panic is acceptable, and
            // a (vanishingly unlikely) success must re-encode cleanly
            match Msg::decode(bytes) {
                Ok((msg, used)) => {
                    ensure(used <= bytes.len(), "consumed within input")?;
                    ensure(msg.encode().len() == used, "reencode length")
                }
                Err(e) => ensure(!e.kind_name().is_empty(), "typed error"),
            }
        },
    );
}

#[test]
fn prop_wire_single_bit_flips_are_always_rejected() {
    use mezo::wire::Msg;
    forall(
        250,
        73,
        |rng| {
            let msg = gen_wire_msg(rng);
            let bytes = msg.encode();
            let bit = rng.below(bytes.len() * 8);
            (bytes, bit)
        },
        |(bytes, bit)| {
            let mut corrupt = bytes.clone();
            corrupt[bit / 8] ^= 1 << (bit % 8);
            // the digest covers version/kind/len/payload and the trailer
            // is the digest itself: every single-bit flip must surface as
            // a typed error (magic/version/kind/len flips hit their own
            // arms before the digest check)
            match Msg::decode(&corrupt) {
                Ok(_) => Err(format!("bit {} flip went undetected", bit)),
                Err(e) => ensure(
                    matches!(
                        e.kind_name(),
                        "bad_magic"
                            | "bad_version"
                            | "unknown_kind"
                            | "truncated"
                            | "oversize"
                            | "bad_digest"
                            | "bad_payload"
                    ),
                    format!("unexpected arm {} for bit {}", e.kind_name(), bit),
                ),
            }
        },
    );
}

#[test]
fn prop_wire_every_truncation_is_rejected() {
    use mezo::wire::Msg;
    forall(
        60,
        74,
        |rng| gen_wire_msg(rng).encode(),
        |bytes| {
            // sample prefixes densely near the boundaries, sparsely inside
            let mut cuts: Vec<usize> = (0..bytes.len().min(32)).collect();
            cuts.extend((0..bytes.len()).step_by(97));
            cuts.push(bytes.len().saturating_sub(1));
            for cut in cuts {
                if Msg::decode(&bytes[..cut]).is_ok() {
                    return Err(format!("{}-byte prefix of {} decoded", cut, bytes.len()));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_wire_plan_frames_guard_their_embedded_digest() {
    use mezo::wire::Msg;
    forall(
        100,
        75,
        gen_wire_plan,
        |plan| {
            // a plan frame whose claimed digest disagrees with the
            // structure must be rejected even though the FRAME digest is
            // valid (this is the cross-peer derivation guard): rebuild
            // the frame around a tampered claimed digest
            let msg = Msg::Plan(Box::new(plan.clone()));
            let good = msg.encode();
            let mut payload =
                good[mezo::wire::HEADER_LEN..good.len() - mezo::wire::TRAILER_LEN].to_vec();
            let n = payload.len();
            payload[n - 1] ^= 0x40; // the claimed digest is the last payload field
            let mut evil = Vec::new();
            evil.extend_from_slice(&mezo::wire::MAGIC);
            evil.push(mezo::wire::VERSION);
            evil.push(msg.kind());
            evil.extend_from_slice(&(payload.len() as u32).to_le_bytes());
            evil.extend_from_slice(&payload);
            evil.extend_from_slice(
                &mezo::wire::frame_digest(mezo::wire::VERSION, msg.kind(), &payload).to_le_bytes(),
            );
            match Msg::decode(&evil) {
                Ok(_) => Err("tampered plan digest accepted".into()),
                Err(e) => ensure(
                    e.kind_name() == "bad_payload",
                    format!("expected bad_payload, got {}", e.kind_name()),
                ),
            }
        },
    );
}

#[test]
fn wire_shard_edges_survive_the_wire() {
    use mezo::wire::Msg;
    // empty trailing shards (more shards than coordinates) roundtrip
    // with digests intact
    let specs = vec![TensorDesc { name: "w".into(), shape: vec![3], dtype: "f32".into() }];
    let p = ParamStore::from_specs(specs);
    let plan = mezo::shard::ShardPlan::new(&p, 8).unwrap();
    assert!(plan.shards().iter().any(|s| s.is_empty()), "degenerate plan has empty shards");
    let bytes = Msg::Plan(Box::new(plan.clone())).encode();
    match Msg::decode(&bytes).unwrap().0 {
        Msg::Plan(back) => {
            assert_eq!(*back, plan);
            assert_eq!(back.digest(), plan.digest());
            for k in 0..plan.n_shards() {
                assert_eq!(back.shard_digest(k), plan.shard_digest(k));
            }
        }
        other => panic!("expected a plan frame, got {}", other.kind_name()),
    }
    // an empty shard's LoadShard carries zero buffers and roundtrips
    let empty_k = plan.shards().iter().position(|s| s.is_empty()).unwrap();
    let load = Msg::LoadShard {
        plan: Box::new(plan.clone()),
        shard: empty_k as u32,
        trainable: vec!["w".into()],
        segments: Vec::new(),
    };
    assert_eq!(Msg::decode(&load.encode()).unwrap().0, load);
    // K=1 degenerate "fleet" plan roundtrips too
    let one = mezo::shard::ShardPlan::new(&p, 1).unwrap();
    let bytes = Msg::Manifest(one.manifest()).encode();
    assert_eq!(Msg::decode(&bytes).unwrap().0, Msg::Manifest(one.manifest()));
}
