//! Acceptance suite for the observability layer: the neutrality gate
//! (the crown jewel — flipping `MEZO_OBS` between fully-off and full
//! span timing must not move a single bit of dense, masked, sharded or
//! quantized stepping, replay, or serving), plus histogram semantics
//! under concurrent recording, level gating, and the Prometheus
//! renderer's output shape. `scripts/verify.sh` re-runs this file with
//! `MEZO_OBS=2` under the full `MEZO_THREADS` × `MEZO_SIMD` matrix.
//!
//! Tests that flip the process-wide level serialize on [`LEVEL_LOCK`]
//! and restore the previous level before asserting, so they compose
//! with the test harness running everything else in parallel.

use mezo::model::meta::TensorDesc;
use mezo::model::params::ParamStore;
use mezo::model::quant::QuantStore;
use mezo::obs::{self, Counter, Gauge, Histo, Level, Registry, Span};
use mezo::optim::mezo::{MezoConfig, MezoSgd, StepRecord};
use mezo::rng::Pcg;
use mezo::serve::{ServeConfig, ServeStore, UserLog};
use mezo::shard::{ShardPlan, ShardedStore};
use mezo::storage::Trajectory;
use mezo::zkernel::{QBits, Sensitivity, SparseMask};
use std::sync::{Arc, Mutex, MutexGuard};

/// Serializes the tests that flip the process-wide obs level.
static LEVEL_LOCK: Mutex<()> = Mutex::new(());

/// Take the level lock, shrugging off poison: a failed level test must
/// not cascade into spurious failures here.
fn level_lock() -> MutexGuard<'static, ()> {
    LEVEL_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn store_with(seed: u64, shapes: &[(&str, usize)]) -> ParamStore {
    let specs = shapes
        .iter()
        .map(|(n, l)| TensorDesc { name: (*n).into(), shape: vec![*l], dtype: "f32".into() })
        .collect();
    let mut p = ParamStore::from_specs(specs);
    p.init(seed);
    p
}

fn bits(p: &ParamStore) -> Vec<u32> {
    p.data.iter().flatten().map(|x| x.to_bits()).collect()
}

/// One deterministic pass over every numeric seam the obs layer
/// instruments: dense and masked MeZO stepping, dense / sharded /
/// masked trajectory replay, quantized masked stepping, and cached
/// serving. Returns the concatenated bit patterns of every result.
fn workload_bits() -> Vec<u32> {
    let base = store_with(91, &[("emb", 600), ("w", 517)]);
    let names: Vec<String> = vec!["emb".into(), "w".into()];
    let cfg = MezoConfig { lr: 1e-2, eps: 1e-3, ..Default::default() };
    let mut out = Vec::new();

    // dense stepping (pool dispatch, optimizer metrics)
    let mut dense = base.clone();
    let mut opt = MezoSgd::new(cfg.clone(), vec![0, 1], 7);
    let mut script = Pcg::new(11);
    for _ in 0..8 {
        opt.step(&mut dense, |_| Ok(script.next_f32() - 0.5)).unwrap();
    }
    out.extend(bits(&dense));

    // masked stepping on the SensZOQ path
    let mask = SparseMask::top_k(&base, &[0, 1], 96, Sensitivity::Magnitude).unwrap();
    let mut masked = base.clone();
    let mut opt_m = MezoSgd::new(cfg.clone(), vec![0, 1], 8);
    opt_m.mask = Some(mask.clone());
    let mut script = Pcg::new(12);
    for _ in 0..8 {
        opt_m.step(&mut masked, |_| Ok(script.next_f32() - 0.5)).unwrap();
    }
    out.extend(bits(&masked));

    // quantized masked stepping, compared via dequantization
    let mut quant = QuantStore::quantize(&base, QBits::Int8, Some(&mask)).unwrap();
    let mut opt_q = MezoSgd::new(cfg, vec![0, 1], 8);
    opt_q.mask = Some(mask.clone());
    let mut script = Pcg::new(12);
    for _ in 0..8 {
        opt_q.step(&mut quant, |_| Ok(script.next_f32() - 0.5)).unwrap();
    }
    out.extend(bits(&quant.to_dense()));

    // replay: the same log applied dense, sharded, and masked
    let recs: Vec<StepRecord> = (0..10)
        .map(|i| StepRecord {
            seed: 900 + i as u64,
            pgrad: 0.05 * i as f32 - 0.2,
            lr: 2e-3,
        })
        .collect();
    let traj = Trajectory::from_run(names.clone(), &recs);
    let mut replayed = base.clone();
    traj.replay(&mut replayed);
    out.extend(bits(&replayed));

    let plan = ShardPlan::new(&base, 3).unwrap();
    let mut sharded = ShardedStore::scatter(&plan, &base).unwrap();
    traj.replay_sharded(&mut sharded, &plan.manifest()).unwrap();
    let mut gathered = base.clone();
    sharded.gather_into(&mut gathered).unwrap();
    out.extend(bits(&gathered));

    let masked_traj =
        Trajectory::from_run(names.clone(), &recs).with_mask_digest(mask.digest());
    let mut replayed_m = base.clone();
    masked_traj.replay_masked(&mut replayed_m, &mask).unwrap();
    out.extend(bits(&replayed_m));

    // serving: hit, miss and base paths (the clock()-guarded seams)
    let mut serve =
        ServeStore::new(base.clone(), ServeConfig { cache_capacity: 1 });
    serve.admit(1, UserLog::dense(traj.clone())).unwrap();
    serve
        .admit(2, UserLog::masked(masked_traj.clone(), Arc::new(mask.clone())))
        .unwrap();
    for user in [1u64, 2, 1, 1] {
        out.extend(bits(&serve.get(user).unwrap()));
    }

    out
}

#[test]
fn obs_level_is_invisible_to_numerics() {
    let _g = level_lock();
    let prev = obs::level();
    obs::set_level(Level::Off);
    let off = workload_bits();
    obs::set_level(Level::Spans);
    let spans = workload_bits();
    obs::set_level(prev);
    assert_eq!(
        off, spans,
        "MEZO_OBS=0 vs MEZO_OBS=2 moved bits — instrumentation touched the numerics"
    );
}

#[test]
fn counters_and_gauges_gate_on_the_level() {
    let _g = level_lock();
    let prev = obs::level();
    let c = Counter::new();
    let gauge = Gauge::new();
    obs::set_level(Level::Off);
    c.inc();
    c.add(5);
    gauge.set(3.5);
    assert_eq!(c.get(), 0, "counter moved at Level::Off");
    assert_eq!(gauge.get(), 0.0, "gauge moved at Level::Off");
    obs::set_level(Level::Counters);
    c.inc();
    c.add(4);
    gauge.set(2.5);
    obs::set_level(prev);
    assert_eq!(c.get(), 5);
    assert_eq!(gauge.get(), 2.5);
}

#[test]
fn spans_read_the_clock_only_at_level_2() {
    let _g = level_lock();
    let prev = obs::level();
    let h = Histo::new();
    obs::set_level(Level::Counters);
    drop(Span::start(&h));
    assert!(obs::clock().is_none(), "clock() live below Level::Spans");
    assert_eq!(h.snapshot().count(), 0, "span recorded below Level::Spans");
    obs::set_level(Level::Spans);
    drop(Span::start(&h));
    obs::record_since(obs::clock(), &h);
    obs::set_level(prev);
    assert_eq!(h.snapshot().count(), 2);
}

#[test]
fn snapshot_under_concurrent_recording_is_monotone_and_finally_exact() {
    const WRITERS: u64 = 4;
    const PER: u64 = 20_000;
    let h = Arc::new(Histo::new());
    let handles: Vec<_> = (0..WRITERS)
        .map(|w| {
            let h = Arc::clone(&h);
            std::thread::spawn(move || {
                for i in 0..PER {
                    // each writer records a disjoint value range, so the
                    // final sum is the exact 0..WRITERS*PER triangle sum
                    h.record(w * PER + i);
                }
            })
        })
        .collect();
    // a concurrent snapshot is a valid histogram of some subset of the
    // observations: counts never exceed what was issued, and successive
    // snapshots never lose counts (per-bucket relaxed loads respect
    // each atomic's modification order)
    let mut last = 0u64;
    for _ in 0..200 {
        let c = h.snapshot().count();
        assert!(c >= last, "snapshot count went backwards: {} -> {}", last, c);
        assert!(c <= WRITERS * PER, "snapshot overshot: {}", c);
        last = c;
    }
    for t in handles {
        t.join().unwrap();
    }
    let s = h.snapshot();
    let n = WRITERS * PER;
    assert_eq!(s.count(), n);
    assert_eq!(s.sum(), n * (n - 1) / 2);
}

#[test]
fn render_text_has_the_pinned_prometheus_shape() {
    let text = {
        // hold the lock only while touching the level-gated registry
        let _g = level_lock();
        let prev = obs::level();
        obs::set_level(Level::Counters);
        mezo::obs::metrics::KERNEL_DISPATCHES
            [mezo::obs::metrics::KernelFamily::Axpy as usize]
            .inc();
        let text = Registry::render_text();
        obs::set_level(prev);
        text
    };
    // headers + one representative line of each renderer form; values
    // are NOT pinned (the registry is process-global and other tests
    // bump it concurrently)
    for needle in [
        "# TYPE mezo_kernel_dispatches_total counter\n",
        "mezo_kernel_dispatches_total{family=\"axpy\"} ",
        "mezo_kernel_dispatches_total{family=\"multi_sgd\"} ",
        "# TYPE mezo_kernel_ns summary\n",
        "mezo_kernel_ns{family=\"axpy\",quantile=\"0.99\"} ",
        "mezo_kernel_ns_count{family=\"axpy\"} ",
        "# TYPE mezo_pool_workers gauge\n",
        "mezo_fleet_rpc_ns{kind=\"perturb\",quantile=\"0.5\"} ",
        "mezo_worker_frames_total{kind=\"shard_slice\"} ",
        "# TYPE mezo_serve_requests_total counter\n",
        "mezo_serve_hit_ns{quantile=\"0.9\"} ",
        "mezo_serve_materialize_ns_sum ",
        "# TYPE mezo_opt_steps_total counter\n",
        "# TYPE mezo_opt_loss gauge\n",
    ] {
        assert!(text.contains(needle), "snapshot lacks {:?}", needle);
    }
    // zero-valued series are included: the line set is level- and
    // load-independent, only the values move
    assert!(text.ends_with('\n'));
    for line in text.lines() {
        if line.starts_with("# TYPE ") {
            continue;
        }
        let (name, value) = line.rsplit_once(' ').unwrap_or_else(|| {
            panic!("metric line without a value: {:?}", line)
        });
        assert!(!name.is_empty(), "empty metric name in {:?}", line);
        assert!(
            value.parse::<f64>().is_ok(),
            "unparseable value {:?} in {:?}",
            value,
            line
        );
    }
}

#[test]
fn kernel_dispatch_counts_once_per_entry_and_times_at_span_level() {
    use mezo::obs::metrics::{KernelFamily, KERNEL_DISPATCHES, KERNEL_NS};
    let _g = level_lock();
    let prev = obs::level();
    let fam = KernelFamily::Ema; // quiet family: no other test drives ema here
    obs::set_level(Level::Counters);
    let c0 = KERNEL_DISPATCHES[fam as usize].get();
    let n0 = KERNEL_NS[fam as usize].snapshot().count();
    drop(obs::kernel_dispatch(fam));
    assert_eq!(KERNEL_DISPATCHES[fam as usize].get(), c0 + 1);
    assert_eq!(
        KERNEL_NS[fam as usize].snapshot().count(),
        n0,
        "latency recorded below span level"
    );
    obs::set_level(Level::Spans);
    drop(obs::kernel_dispatch(fam));
    obs::set_level(prev);
    assert_eq!(KERNEL_DISPATCHES[fam as usize].get(), c0 + 2);
    assert_eq!(KERNEL_NS[fam as usize].snapshot().count(), n0 + 1);
}
