"""L2: the transformer LM compute graph (fwd + loss + grad), in JAX.

Two model families share the code path:
  * ``ar``  — autoregressive, causal attention, next-token prediction
              (the paper's OPT family analog);
  * ``mlm`` — bidirectional masked LM (the RoBERTa-large analog; label
              words fill a [MASK] position under a prompt template).

Tuning modes (paper §3 / Appendix E.5):
  * ``full``   — every parameter trainable;
  * ``lora``   — frozen base + rank-r deltas on each layer's W_q and W_v
                 (Hu et al. 2022, eq. 6: W + (alpha/r)·A·B);
  * ``prefix`` — frozen base + m tuned key/value rows prepended at every
                 attention layer (Li & Liang 2021).

The forward hot-spots call the L1 Pallas kernels (``use_pallas=True``; the
artifacts rust executes at runtime are lowered this way). The backprop
baseline artifacts are lowered through the pure-jnp references
(``use_pallas=False``) so ``jax.grad`` never differentiates through
``pallas_call``; the two paths are asserted allclose in python/tests.

Everything here is build-time only: ``aot.py`` lowers these functions once to
HLO text and rust never imports python again.
"""

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import kernels as K
from .kernels import ref

# Canonical size ladder (paper: RoBERTa-large 350M … OPT-66B; here the same
# architecture scaled to a 1-CPU-core testbed — see DESIGN.md §2.2).
SIZES = {
    "tiny": dict(d_model=64, n_layers=2, n_heads=2, d_ff=256),
    "small": dict(d_model=128, n_layers=4, n_heads=4, d_ff=512),
    "base": dict(d_model=256, n_layers=6, n_heads=8, d_ff=1024),
    "large": dict(d_model=512, n_layers=8, n_heads=8, d_ff=2048),
    # 'xl' exists only for the analytic memory model (Fig. 3/4); it is never
    # lowered by default.
    "xl": dict(d_model=1024, n_layers=12, n_heads=16, d_ff=4096),
}

VOCAB_SIZE = 512
MAX_SEQ = 64


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    family: str = "ar"          # 'ar' | 'mlm'
    size: str = "tiny"
    vocab: int = VOCAB_SIZE
    max_seq: int = MAX_SEQ
    tuning: str = "full"        # 'full' | 'lora' | 'prefix'
    lora_r: int = 8
    lora_alpha: int = 16
    prefix_len: int = 8

    @property
    def dims(self):
        return SIZES[self.size]

    @property
    def d_model(self):
        return self.dims["d_model"]

    @property
    def n_layers(self):
        return self.dims["n_layers"]

    @property
    def n_heads(self):
        return self.dims["n_heads"]

    @property
    def d_ff(self):
        return self.dims["d_ff"]

    @property
    def head_dim(self):
        return self.d_model // self.n_heads


def base_param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) for the frozen/base transformer parameters.

    The order here is the artifact ABI: rust passes buffers in exactly this
    order (recorded in the .meta.json sidecar).
    """
    d, f = cfg.d_model, cfg.d_ff
    specs = [
        ("embed.tok", (cfg.vocab, d)),
        ("embed.pos", (cfg.max_seq, d)),
    ]
    for i in range(cfg.n_layers):
        p = f"layer{i}"
        specs += [
            (f"{p}.ln1.g", (d,)), (f"{p}.ln1.b", (d,)),
            (f"{p}.attn.wq", (d, d)), (f"{p}.attn.bq", (d,)),
            (f"{p}.attn.wk", (d, d)), (f"{p}.attn.bk", (d,)),
            (f"{p}.attn.wv", (d, d)), (f"{p}.attn.bv", (d,)),
            (f"{p}.attn.wo", (d, d)), (f"{p}.attn.bo", (d,)),
            (f"{p}.ln2.g", (d,)), (f"{p}.ln2.b", (d,)),
            (f"{p}.mlp.w1", (d, f)), (f"{p}.mlp.b1", (f,)),
            (f"{p}.mlp.w2", (f, d)), (f"{p}.mlp.b2", (d,)),
        ]
    specs += [("final_ln.g", (d,)), ("final_ln.b", (d,))]
    return specs


def extra_param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Tuning-mode parameters appended after the base parameters."""
    specs = []
    if cfg.tuning == "lora":
        for i in range(cfg.n_layers):
            for which in ("q", "v"):
                specs += [
                    (f"layer{i}.lora_{which}.a", (cfg.d_model, cfg.lora_r)),
                    (f"layer{i}.lora_{which}.b", (cfg.lora_r, cfg.d_model)),
                ]
    elif cfg.tuning == "prefix":
        for i in range(cfg.n_layers):
            specs += [
                (f"layer{i}.prefix.k", (cfg.prefix_len, cfg.d_model)),
                (f"layer{i}.prefix.v", (cfg.prefix_len, cfg.d_model)),
            ]
    return specs


def param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    return base_param_specs(cfg) + extra_param_specs(cfg)


def trainable_names(cfg: ModelConfig) -> List[str]:
    """Which parameters the optimizer may touch (paper §3: full vs PEFT)."""
    if cfg.tuning == "full":
        return [n for n, _ in base_param_specs(cfg)]
    return [n for n, _ in extra_param_specs(cfg)]


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def _split_heads(x, n_heads):
    b, s, d = x.shape
    return x.reshape(b, s, n_heads, d // n_heads).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, s, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * dh)


def _layernorm(x, g, b, use_pallas):
    bsz, s, d = x.shape
    if use_pallas:
        return K.layernorm(x.reshape(bsz * s, d), g, b).reshape(bsz, s, d)
    return ref.layernorm_ref(x, g, b)


def _linear(x, w, b, activation, use_pallas):
    bsz, s, din = x.shape
    dout = w.shape[1]
    if use_pallas:
        y = K.linear(x.reshape(bsz * s, din), w, b, activation)
        return y.reshape(bsz, s, dout)
    return ref.linear_ref(x, w, b, activation)


def _attention(q, k, v, key_mask, causal, use_pallas):
    if use_pallas:
        return K.attention(q, k, v, key_mask, causal)
    return ref.attention_ref(q, k, v, key_mask, causal)


def forward(cfg: ModelConfig, params: Dict[str, jax.Array], input_ids,
            attn_mask, use_pallas: bool):
    """Hidden states (B, S, D). attn_mask: (B, S) float, 1 = real token."""
    b, s = input_ids.shape
    causal = cfg.family == "ar"
    x = params["embed.tok"][input_ids] + params["embed.pos"][:s][None, :, :]
    for i in range(cfg.n_layers):
        p = f"layer{i}"
        h = _layernorm(x, params[f"{p}.ln1.g"], params[f"{p}.ln1.b"], use_pallas)

        wq, wv = params[f"{p}.attn.wq"], params[f"{p}.attn.wv"]
        if cfg.tuning == "lora":
            scale = cfg.lora_alpha / cfg.lora_r
            wq = wq + scale * (params[f"{p}.lora_q.a"] @ params[f"{p}.lora_q.b"])
            wv = wv + scale * (params[f"{p}.lora_v.a"] @ params[f"{p}.lora_v.b"])

        q = _linear(h, wq, params[f"{p}.attn.bq"], None, use_pallas)
        k = _linear(h, params[f"{p}.attn.wk"], params[f"{p}.attn.bk"], None, use_pallas)
        v = _linear(h, wv, params[f"{p}.attn.bv"], None, use_pallas)
        q = _split_heads(q, cfg.n_heads)
        k = _split_heads(k, cfg.n_heads)
        v = _split_heads(v, cfg.n_heads)

        key_mask = attn_mask
        if cfg.tuning == "prefix":
            pk = _split_heads(
                jnp.broadcast_to(params[f"{p}.prefix.k"][None],
                                 (b, cfg.prefix_len, cfg.d_model)), cfg.n_heads)
            pv = _split_heads(
                jnp.broadcast_to(params[f"{p}.prefix.v"][None],
                                 (b, cfg.prefix_len, cfg.d_model)), cfg.n_heads)
            k = jnp.concatenate([pk, k], axis=2)
            v = jnp.concatenate([pv, v], axis=2)
            key_mask = jnp.concatenate(
                [jnp.ones((b, cfg.prefix_len), attn_mask.dtype), attn_mask], axis=1)

        a = _attention(q, k, v, key_mask, causal, use_pallas)
        a = _linear(_merge_heads(a), params[f"{p}.attn.wo"],
                    params[f"{p}.attn.bo"], None, use_pallas)
        x = x + a

        h = _layernorm(x, params[f"{p}.ln2.g"], params[f"{p}.ln2.b"], use_pallas)
        h = _linear(h, params[f"{p}.mlp.w1"], params[f"{p}.mlp.b1"], "gelu", use_pallas)
        h = _linear(h, params[f"{p}.mlp.w2"], params[f"{p}.mlp.b2"], None, use_pallas)
        x = x + h
    x = _layernorm(x, params["final_ln.g"], params["final_ln.b"], use_pallas)
    return x


def logits_from_hidden(params, hidden):
    """Tied LM head: logits = h @ E^T."""
    return hidden @ params["embed.tok"].T


def loss_fn(cfg: ModelConfig, params, input_ids, targets, loss_mask,
            attn_mask, use_pallas: bool):
    """Returns (mean_loss, per_example_loss (B,)).

    per_example_loss is the *mean* CE over each example's masked positions —
    exactly the "average log-likelihood (by tokens)" the paper scores
    classification / multiple-choice candidates with (Appendix E.4).
    """
    b, s = input_ids.shape
    hidden = forward(cfg, params, input_ids, attn_mask, use_pallas)
    logits = logits_from_hidden(params, hidden)
    if use_pallas:
        per_pos = K.softmax_xent(
            logits.reshape(b * s, cfg.vocab),
            targets.reshape(b * s), loss_mask.reshape(b * s)).reshape(b, s)
    else:
        per_pos = ref.softmax_xent_ref(logits, targets, loss_mask)
    denom = jnp.maximum(jnp.sum(loss_mask, axis=1), 1e-6)
    per_example = jnp.sum(per_pos, axis=1) / denom
    mean_loss = jnp.sum(per_pos) / jnp.maximum(jnp.sum(loss_mask), 1e-6)
    return mean_loss, per_example


def logits_features_fn(cfg: ModelConfig, params, input_ids, attn_mask,
                       use_pallas: bool):
    """Returns (logits (B,S,V), hidden (B,S,D)) — used by rust for
    evaluation (label-word scoring, greedy decode) and linear probing."""
    hidden = forward(cfg, params, input_ids, attn_mask, use_pallas)
    return logits_from_hidden(params, hidden), hidden


def grad_fn(cfg: ModelConfig, params, input_ids, targets, loss_mask, attn_mask):
    """Backprop baseline: (loss, grads in trainable_names order).

    Lowered through the jnp reference path (see module docstring).
    """
    tnames = trainable_names(cfg)
    frozen = {n: v for n, v in params.items() if n not in set(tnames)}

    def f(trainable):
        full = dict(frozen)
        full.update(trainable)
        mean_loss, _ = loss_fn(cfg, full, input_ids, targets, loss_mask,
                               attn_mask, use_pallas=False)
        return mean_loss

    trainable = {n: params[n] for n in tnames}
    loss, grads = jax.value_and_grad(f)(trainable)
    return loss, [grads[n] for n in tnames]


def kv_activations_fn(cfg: ModelConfig, params, input_ids, attn_mask):
    """Per-layer (k, v) activations for the given tokens — the paper's
    'real activation' prefix initialisation (Appendix E.5 / Table 17).

    Returns a flat list [k0, v0, k1, v1, ...], each (S, d_model) for batch 1.

    Every parameter is "anchored" into the outputs (×0 contribution): XLA
    prunes unused entry parameters during lowering, which would break the
    fixed ABI rust marshals buffers against.
    """
    anchor = sum(jnp.sum(p) * 0.0 for p in params.values())
    b, s = input_ids.shape
    causal = cfg.family == "ar"
    x = params["embed.tok"][input_ids] + params["embed.pos"][:s][None, :, :]
    outs = []
    for i in range(cfg.n_layers):
        p = f"layer{i}"
        h = ref.layernorm_ref(x, params[f"{p}.ln1.g"], params[f"{p}.ln1.b"])
        q = ref.linear_ref(h, params[f"{p}.attn.wq"], params[f"{p}.attn.bq"])
        k = ref.linear_ref(h, params[f"{p}.attn.wk"], params[f"{p}.attn.bk"])
        v = ref.linear_ref(h, params[f"{p}.attn.wv"], params[f"{p}.attn.bv"])
        outs += [k[0] + anchor, v[0] + anchor]
        a = ref.attention_ref(_split_heads(q, cfg.n_heads),
                              _split_heads(k, cfg.n_heads),
                              _split_heads(v, cfg.n_heads), attn_mask, causal)
        a = ref.linear_ref(_merge_heads(a), params[f"{p}.attn.wo"],
                           params[f"{p}.attn.bo"])
        x = x + a
        h = ref.layernorm_ref(x, params[f"{p}.ln2.g"], params[f"{p}.ln2.b"])
        h = ref.linear_ref(h, params[f"{p}.mlp.w1"], params[f"{p}.mlp.b1"], "gelu")
        h = ref.linear_ref(h, params[f"{p}.mlp.w2"], params[f"{p}.mlp.b2"])
        x = x + h
    return outs


def mezo_fused_step_fn(cfg: ModelConfig, params, input_ids, targets,
                       loss_mask, attn_mask, seed, eps, lr):
    """Perf-variant (§Perf L3): a whole MeZO step as ONE XLA execution.

    z is regenerated per-tensor from `seed` (threefry fold_in), the two SPSA
    forward passes run back-to-back, and the in-place update
    theta <- theta - lr * projected_grad * z is applied via the L1 SPSA
    kernel. Outputs (updated trainable..., loss_plus, loss_minus, pgrad).

    NOTE: this trades Algorithm 1's 4x z regeneration for XLA-fused compute;
    rust's MezoSgd remains the faithful in-place implementation and is what
    the headline results use. z here comes from jax's threefry stream, so
    fused steps and rust-native steps are *statistically* identical but not
    bit-identical (documented in EXPERIMENTS.md).
    """
    tnames = trainable_names(cfg)
    frozen = {n: v for n, v in params.items() if n not in set(tnames)}
    key = jax.random.PRNGKey(seed[0])

    def perturbed(sign):
        full = dict(frozen)
        for idx, n in enumerate(tnames):
            z = jax.random.normal(jax.random.fold_in(key, idx),
                                  params[n].shape, params[n].dtype)
            flat = params[n].reshape(-1)
            pert = K.spsa_perturb(flat, z.reshape(-1), sign * eps)
            full[n] = pert.reshape(params[n].shape)
        return full

    lp, _ = loss_fn(cfg, perturbed(+1.0), input_ids, targets, loss_mask,
                    attn_mask, use_pallas=False)
    lm, _ = loss_fn(cfg, perturbed(-1.0), input_ids, targets, loss_mask,
                    attn_mask, use_pallas=False)
    pgrad = (lp - lm) / (2.0 * eps[0])
    new = []
    for idx, n in enumerate(tnames):
        z = jax.random.normal(jax.random.fold_in(key, idx),
                              params[n].shape, params[n].dtype)
        upd = K.spsa_perturb(params[n].reshape(-1), z.reshape(-1),
                             (-lr[0] * pgrad)[None])
        new.append(upd.reshape(params[n].shape))
    return new + [lp, lm, pgrad]
