"""AOT lowering: JAX/Pallas -> HLO text + metadata, consumed by rust.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids that the xla crate's xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Each artifact is a pure function with a fixed ABI:
    inputs  = [*params (param_specs order), *batch tensors, *extras]
    outputs = tuple (lowered with return_tuple=True)
and ships with a `.meta.json` sidecar describing every input/output tensor,
the trainable set, model dims and a FLOP estimate. Rust reads the sidecar to
allocate parameter buffers and marshal literals — python is never imported at
runtime.

Usage:
    python -m compile.aot --out-dir ../artifacts            # default set
    python -m compile.aot --out-dir ../artifacts --only ar_small_full_loss_b8_s64
    python -m compile.aot --list
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(mode, b, s):
    """Batch tensors appended after params, per artifact mode."""
    ii = ("input_ids", (b, s), "i32")
    tg = ("targets", (b, s), "i32")
    lm = ("loss_mask", (b, s), "f32")
    am = ("attn_mask", (b, s), "f32")
    if mode in ("loss", "loss_pallas", "grad"):
        return [ii, tg, lm, am]
    if mode in ("logits", "kv"):
        return [ii, am]
    if mode == "fused":
        return [ii, tg, lm, am, ("seed", (1,), "i32"),
                ("eps", (1,), "f32"), ("lr", (1,), "f32")]
    raise ValueError(mode)


_DT = {"f32": jnp.float32, "i32": jnp.int32}


def build_fn(cfg: M.ModelConfig, mode):
    """Returns (fn taking flat positional args, input descriptors, output names)."""
    pspecs = M.param_specs(cfg)
    n_params = len(pspecs)
    tnames = M.trainable_names(cfg)

    def unpack(args):
        params = {name: a for (name, _), a in zip(pspecs, args[:n_params])}
        return params, args[n_params:]

    if mode in ("loss", "loss_pallas"):
        use_pallas = mode == "loss_pallas"

        def fn(*args):
            params, (ii, tg, lm, am) = unpack(args)
            mean, per_ex = M.loss_fn(cfg, params, ii, tg, lm, am, use_pallas)
            return mean, per_ex
        outs = ["mean_loss", "per_example_loss"]
    elif mode == "logits":
        def fn(*args):
            params, (ii, am) = unpack(args)
            return M.logits_features_fn(cfg, params, ii, am, use_pallas=False)
        outs = ["logits", "hidden"]
    elif mode == "grad":
        def fn(*args):
            params, (ii, tg, lm, am) = unpack(args)
            loss, grads = M.grad_fn(cfg, params, ii, tg, lm, am)
            return tuple([loss] + grads)
        outs = ["loss"] + [f"grad.{n}" for n in tnames]
    elif mode == "kv":
        def fn(*args):
            params, (ii, am) = unpack(args)
            return tuple(M.kv_activations_fn(cfg, params, ii, am))
        outs = []
        for i in range(cfg.n_layers):
            outs += [f"kv.layer{i}.k", f"kv.layer{i}.v"]
    elif mode == "fused":
        def fn(*args):
            params, (ii, tg, lm, am, seed, eps, lr) = unpack(args)
            res = M.mezo_fused_step_fn(cfg, params, ii, tg, lm, am, seed, eps, lr)
            return tuple(res)
        outs = [f"new.{n}" for n in tnames] + ["loss_plus", "loss_minus", "pgrad"]
    else:
        raise ValueError(mode)
    return fn, outs


def flops_forward(cfg: M.ModelConfig, b, s):
    """2*MACs estimate of one forward pass (matmuls only)."""
    d, f, L = cfg.d_model, cfg.d_ff, cfg.n_layers
    per_tok = L * (4 * d * d + 2 * d * f) + cfg.vocab * d
    attn = L * 2 * s * d  # scores + weighted sum per token
    return 2 * b * s * (per_tok + attn)


def artifact_name(cfg: M.ModelConfig, mode, b, s):
    return f"{cfg.family}_{cfg.size}_{cfg.tuning}_{mode}_b{b}_s{s}"


def lower_artifact(cfg: M.ModelConfig, mode, b, s, out_dir):
    name = artifact_name(cfg, mode, b, s)
    fn, out_names = build_fn(cfg, mode)
    pspecs = M.param_specs(cfg)
    bspecs = batch_specs(mode, b, s)
    in_specs = (
        [_spec(shape) for _, shape in pspecs]
        + [_spec(shape, _DT[dt]) for _, shape, dt in bspecs])
    lowered = jax.jit(fn).lower(*in_specs)
    text = to_hlo_text(lowered)
    # Derive output shapes by abstract evaluation (robust across jax versions).
    out_avals = jax.eval_shape(fn, *in_specs)
    if not isinstance(out_avals, tuple):
        out_avals = (out_avals,)
    out_shapes = [
        {"name": n, "shape": list(map(int, a.shape)), "dtype": str(a.dtype)}
        for n, a in zip(out_names, out_avals)]

    meta = {
        "name": name,
        "family": cfg.family,
        "size": cfg.size,
        "tuning": cfg.tuning,
        "mode": mode,
        "batch": b,
        "seq": s,
        "vocab": cfg.vocab,
        "max_seq": cfg.max_seq,
        "dims": {"d_model": cfg.d_model, "n_layers": cfg.n_layers,
                 "n_heads": cfg.n_heads, "d_ff": cfg.d_ff,
                 "head_dim": cfg.head_dim},
        "lora_r": cfg.lora_r,
        "lora_alpha": cfg.lora_alpha,
        "prefix_len": cfg.prefix_len,
        "params": [{"name": n, "shape": list(sh)} for n, sh in pspecs],
        "trainable": M.trainable_names(cfg),
        "batch_inputs": [{"name": n, "shape": list(sh), "dtype": dt}
                         for n, sh, dt in bspecs],
        "outputs": out_shapes,
        "flops_forward": flops_forward(cfg, b, s),
        "n_params": int(sum(
            int(jnp.prod(jnp.asarray(sh))) for _, sh in pspecs)),
    }
    os.makedirs(out_dir, exist_ok=True)
    hlo_path = os.path.join(out_dir, name + ".hlo.txt")
    with open(hlo_path, "w") as f:
        f.write(text)
    with open(os.path.join(out_dir, name + ".meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    return name, len(text)


# (family, size, tuning, mode, batch, seq) — the default artifact set.
B, S = 8, 64


def default_set():
    arts = []
    for family in ("ar", "mlm"):
        for size in ("tiny", "small"):
            arts += [
                (family, size, "full", "loss", B, S),
                (family, size, "full", "loss_pallas", B, S),
                (family, size, "full", "logits", B, S),
                (family, size, "full", "grad", B, S),
            ]
        # PEFT variants at the headline size.
        for tuning in ("lora", "prefix"):
            arts += [
                (family, "small", tuning, "loss", B, S),
                (family, "small", tuning, "grad", B, S),
                (family, "small", tuning, "logits", B, S),
                (family, "tiny", tuning, "logits", B, S),
            ]
        arts += [(family, "small", "prefix", "kv", 1, 8)]
    # Scaling ladder for wall-clock / memory studies (ar family, like OPT).
    for size in ("base", "large"):
        arts += [
            ("ar", size, "full", "loss", B, S),
            ("ar", size, "full", "logits", B, S),
            ("ar", size, "full", "grad", B, S),
        ]
    # Fused-step perf variant.
    arts += [("ar", "tiny", "full", "fused", B, S),
             ("ar", "small", "full", "fused", B, S)]
    # PEFT for tiny (ablations run at tiny scale).
    for tuning in ("lora", "prefix"):
        arts += [("ar", "tiny", tuning, "loss", B, S),
                 ("mlm", "tiny", tuning, "loss", B, S)]
    arts += [("ar", "tiny", "prefix", "kv", 1, 8),
             ("mlm", "tiny", "prefix", "kv", 1, 8),
             ("mlm", "small", "full", "fused", B, S)]
    return arts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="lower only the artifact with this name")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    todo = default_set()
    if args.list:
        for fam, size, tuning, mode, b, s in todo:
            cfg = M.ModelConfig(family=fam, size=size, tuning=tuning)
            print(artifact_name(cfg, mode, b, s))
        return

    for fam, size, tuning, mode, b, s in todo:
        cfg = M.ModelConfig(family=fam, size=size, tuning=tuning)
        name = artifact_name(cfg, mode, b, s)
        if args.only and name != args.only:
            continue
        hlo_path = os.path.join(args.out_dir, name + ".hlo.txt")
        if not args.only and os.path.exists(hlo_path):
            print(f"[aot] {name}: up to date", flush=True)
            continue
        n, sz = lower_artifact(cfg, mode, b, s, args.out_dir)
        print(f"[aot] wrote {n} ({sz/1e6:.1f} MB hlo text)", flush=True)


if __name__ == "__main__":
    main()
