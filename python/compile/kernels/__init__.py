"""L1 Pallas kernels (interpret=True) + pure-jnp reference oracles."""
from . import ref  # noqa: F401
from .attention import attention  # noqa: F401
from .layernorm import layernorm  # noqa: F401
from .linear import linear  # noqa: F401
from .softmax_xent import softmax_xent  # noqa: F401
from .spsa import spsa_perturb  # noqa: F401
