"""Pure-jnp reference oracles for every Pallas kernel (L1 correctness spec).

Each function here is the mathematical definition the corresponding Pallas
kernel must match (pytest asserts allclose under hypothesis-style sweeps).
The backprop (FT baseline) artifacts are lowered through these references so
`jax.grad` never has to differentiate through `pallas_call`.
"""

import jax
import jax.numpy as jnp

NEG_INF = -1e9


def gelu_ref(x):
    """tanh-approx GELU (matches the kernel epilogue)."""
    c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))


def layernorm_ref(x, gain, bias, eps=1e-5):
    """LayerNorm over the last axis. x: (..., D); gain/bias: (D,)."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gain + bias


def linear_ref(x, w, b=None, activation=None):
    """x: (..., K) @ w: (K, N) + b, with optional fused 'gelu' epilogue."""
    y = x @ w
    if b is not None:
        y = y + b
    if activation == "gelu":
        y = gelu_ref(y)
    return y


def attention_ref(q, k, v, key_mask, causal):
    """Multi-head attention.

    q: (B, H, Sq, Dh); k,v: (B, H, Sk, Dh) with Sk >= Sq (Sk > Sq when a
    tuned prefix is prepended to keys/values — prefix columns are always
    visible under causal masking); key_mask: (B, Sk) with 1=valid key.
    causal: bool (static). Returns (B, H, Sq, Dh).
    """
    b, h, sq, dh = q.shape
    sk = k.shape[2]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, q.dtype))
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    bias = (1.0 - key_mask[:, None, None, :]) * NEG_INF
    scores = scores + bias
    if causal:
        i = jnp.arange(sq)[:, None]
        j = jnp.arange(sk)[None, :]
        scores = jnp.where(j <= i + (sk - sq), scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def softmax_xent_ref(logits, targets, mask):
    """Per-position cross-entropy.

    logits: (B, S, V); targets: (B, S) int32; mask: (B, S) float 1=count.
    Returns per-position loss (B, S), already multiplied by mask.
    """
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return (lse - tgt) * mask


def spsa_perturb_ref(theta, z, eps):
    """In-place SPSA perturbation: theta + eps * z (elementwise)."""
    return theta + eps * z
