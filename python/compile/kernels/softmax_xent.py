"""Fused log-softmax cross-entropy as a Pallas kernel.

Per-row single pass: max, exp-sum and target-logit gather are fused so the
(rows, V) logit tile is read from HBM exactly once and only a (rows,) loss
vector is written back — this is the last op of every MeZO forward pass, so
it sits directly on the 2-forward-passes-per-step critical path.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _xent_kernel(logits_ref, targets_ref, mask_ref, o_ref, *, vocab):
    x = logits_ref[...].astype(jnp.float32)  # (rows, V)
    t = targets_ref[...]  # (rows,)
    m = jnp.max(x, axis=-1)
    lse = m + jnp.log(jnp.sum(jnp.exp(x - m[:, None]), axis=-1))
    onehot = (jax.lax.iota(jnp.int32, vocab)[None, :] == t[:, None])
    tgt = jnp.sum(jnp.where(onehot, x, 0.0), axis=-1)
    o_ref[...] = ((lse - tgt) * mask_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def softmax_xent(logits, targets, mask, block_rows=None):
    """logits: (R, V); targets: (R,) int32; mask: (R,) float.

    Returns masked per-row CE loss (R,). Matches ref.softmax_xent_ref
    (flattened over rows).
    """
    r, v = logits.shape
    block_rows = block_rows or min(64, r)
    assert r % block_rows == 0
    kernel = functools.partial(_xent_kernel, vocab=v)
    return pl.pallas_call(
        kernel,
        grid=(r // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, v), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((r,), jnp.float32),
        interpret=True,
    )(logits, targets, mask)
