"""SPSA perturbation (axpy) as a Pallas kernel.

theta <- theta + eps * z, streamed block-by-block. On TPU this is a pure VPU
op whose working set is one VMEM tile; it is the kernel form of Algorithm 1's
`PerturbParameters` used by the fused-step artifact (the primary MeZO path
performs the same update in-place in rust — see rust/src/optim/mezo.rs).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spsa_kernel(theta_ref, z_ref, eps_ref, o_ref):
    o_ref[...] = theta_ref[...] + eps_ref[0] * z_ref[...]


def spsa_perturb(theta, z, eps, block=4096):
    """theta, z: (N,) f32; eps: scalar array (1,). Returns theta + eps*z."""
    (n,) = theta.shape
    block = min(block, n)
    # Pad to a block multiple so the grid tiles exactly.
    pad = (-n) % block
    if pad:
        theta = jnp.pad(theta, (0, pad))
        z = jnp.pad(z, (0, pad))
    out = pl.pallas_call(
        _spsa_kernel,
        grid=((n + pad) // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n + pad,), theta.dtype),
        interpret=True,
    )(theta, z, eps)
    return out[:n]
