"""Flash-style tiled attention as a Pallas kernel (L1 hot-spot).

TPU adaptation of the paper's stock attention (DESIGN.md §Hardware-Adaptation):
Q is staged through VMEM one (block_q, head_dim) tile at a time via BlockSpec,
and the kernel streams K/V in block_k-sized tiles with an *online softmax*
(running max / running sum), so the S×S score matrix never materialises —
VMEM footprint is O(block_q·d + block_k·d) instead of O(S²).

interpret=True is mandatory here: real-TPU lowering emits a Mosaic
custom-call that the CPU PJRT plugin cannot execute. Correctness is pinned
to `ref.attention_ref` by python/tests/test_kernels.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e9


def _attention_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, *, block_q,
                      block_k, kv_len, causal, causal_offset, scale):
    """One (batch·head, q-block) grid cell: online-softmax over k blocks.

    causal_offset supports prefix-tuning: query i may attend key j when
    j <= i + causal_offset (the first `offset` keys are the always-visible
    tuned prefix).
    """
    q = q_ref[0].astype(jnp.float32)  # (block_q, dh)
    dh = q.shape[-1]
    q_start = pl.program_id(1) * block_q
    row_ids = q_start + jax.lax.iota(jnp.int32, block_q)

    def body(kb, carry):
        m_prev, l_prev, acc_prev = carry
        k_start = kb * block_k
        k = pl.load(k_ref, (0, pl.ds(k_start, block_k), slice(None)))
        v = pl.load(v_ref, (0, pl.ds(k_start, block_k), slice(None)))
        km = pl.load(mask_ref, (0, pl.ds(k_start, block_k)))
        s = jnp.dot(q, k.astype(jnp.float32).T) * scale  # (bq, bk)
        s = s + (1.0 - km.astype(jnp.float32))[None, :] * NEG_INF
        if causal:
            col_ids = k_start + jax.lax.iota(jnp.int32, block_k)
            visible = col_ids[None, :] <= row_ids[:, None] + causal_offset
            s = jnp.where(visible, s, NEG_INF)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc_new = acc_prev * alpha[:, None] + jnp.dot(p, v.astype(jnp.float32))
        return m_new, l_new, acc_new

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, dh), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, kv_len // block_k, body, (m0, l0, acc0))
    # Fully-masked rows (pure padding) have l == 0; emit zeros, not NaN.
    l = jnp.where(l == 0.0, 1.0, l)
    o_ref[0] = (acc / l[:, None]).astype(o_ref.dtype)


def _largest_divisor_block(n, cap=32):
    for c in range(min(cap, n), 0, -1):
        if n % c == 0:
            return c
    return n


def attention(q, k, v, key_mask, causal, block_q=None, block_k=None):
    """Pallas attention. q: (B, H, Sq, Dh); k,v: (B, H, Sk, Dh) with
    Sk >= Sq (Sk > Sq when a tuned prefix is prepended to keys/values);
    key_mask: (B, Sk) 1=valid. Returns (B, H, Sq, Dh).

    Matches ref.attention_ref (with the prefix columns always visible
    under causal masking).
    """
    b, h, sq, dh = q.shape
    sk = k.shape[2]
    offset = sk - sq
    block_q = block_q or _largest_divisor_block(sq)
    block_k = block_k or _largest_divisor_block(sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    scale = 1.0 / (dh**0.5)

    qf = q.reshape(b * h, sq, dh)
    kf = k.reshape(b * h, sk, dh)
    vf = v.reshape(b * h, sk, dh)
    maskf = jnp.repeat(key_mask, h, axis=0)  # (B*H, Sk)

    kernel = functools.partial(
        _attention_kernel, block_q=block_q, block_k=block_k, kv_len=sk,
        causal=causal, causal_offset=offset, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, sq // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda bh, qb: (bh, qb, 0)),
            pl.BlockSpec((1, sk, dh), lambda bh, qb: (bh, 0, 0)),
            pl.BlockSpec((1, sk, dh), lambda bh, qb: (bh, 0, 0)),
            pl.BlockSpec((1, sk), lambda bh, qb: (bh, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda bh, qb: (bh, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, dh), q.dtype),
        interpret=True,
    )(qf, kf, vf, maskf)
    return out.reshape(b, h, sq, dh)
