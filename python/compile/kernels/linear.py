"""Tiled matmul + bias + GELU epilogue as a Pallas kernel.

Classic (M, N, K)-tiled schedule: the grid iterates K innermost, accumulating
partial products into the output tile resident in VMEM; bias-add and the
optional GELU epilogue are fused into the final K step, so the activation
never takes an extra HBM round-trip. On a real TPU the (block_m, block_n)
tile feeds the 128×128 MXU; for this model's small dims the tile is the whole
operand (documented in DESIGN.md §Hardware-Adaptation).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gelu(x):
    c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))


def _linear_kernel(x_ref, w_ref, b_ref, o_ref, *, n_k_blocks, activation):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32)
    ).astype(o_ref.dtype)

    @pl.when(pl.program_id(2) == n_k_blocks - 1)
    def _epilogue():
        y = o_ref[...] + b_ref[...][None, :]
        if activation == "gelu":
            y = _gelu(y)
        o_ref[...] = y


def linear(x, w, b, activation=None, block_m=None, block_n=None, block_k=None):
    """x: (M, K) @ w: (K, N) + b: (N,), optional fused GELU.

    Matches ref.linear_ref.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,)
    block_m = block_m or min(128, m)
    block_n = block_n or min(128, n)
    block_k = block_k or min(128, k)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0
    n_k_blocks = k // block_k
    kernel = functools.partial(
        _linear_kernel, n_k_blocks=n_k_blocks, activation=activation)
    return pl.pallas_call(
        kernel,
        grid=(m // block_m, n // block_n, n_k_blocks),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((block_n,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, w, b)
