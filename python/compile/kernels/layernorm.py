"""Fused LayerNorm as a Pallas kernel.

Row-tiled: each grid cell normalises a (block_rows, D) tile in a single pass
(mean, variance, scale, shift fused — one HBM read + one HBM write per row,
versus the 3+ round-trips of an unfused mean/var/normalize graph).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _layernorm_kernel(x_ref, g_ref, b_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * g_ref[...][None, :] + b_ref[...][None, :]).astype(o_ref.dtype)


def layernorm(x, gain, bias, eps=1e-5, block_rows=None):
    """x: (R, D) -> (R, D), normalised over D. Matches ref.layernorm_ref."""
    r, d = x.shape
    block_rows = block_rows or min(64, r)
    assert r % block_rows == 0, (r, block_rows)
    kernel = functools.partial(_layernorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(r // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), x.dtype),
        interpret=True,
    )(x, gain, bias)
