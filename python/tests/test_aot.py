"""AOT pipeline: artifact metadata is a faithful ABI description, HLO text
parses, and the lowered loss artifact computes what the model computes."""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model as M

jax.config.update("jax_platform_name", "cpu")

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _meta(name):
    path = os.path.join(ART_DIR, name + ".meta.json")
    if not os.path.exists(path):
        pytest.skip(f"artifact {name} not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


def test_default_set_is_unique_and_named_consistently():
    names = set()
    for fam, size, tuning, mode, b, s in aot.default_set():
        cfg = M.ModelConfig(family=fam, size=size, tuning=tuning)
        n = aot.artifact_name(cfg, mode, b, s)
        assert n not in names, f"duplicate artifact {n}"
        names.add(n)
    assert len(names) >= 30


def test_meta_param_count_matches_model():
    meta = _meta("ar_tiny_full_loss_b8_s64")
    cfg = M.ModelConfig(family="ar", size="tiny")
    specs = M.param_specs(cfg)
    assert [p["name"] for p in meta["params"]] == [n for n, _ in specs]
    assert [tuple(p["shape"]) for p in meta["params"]] == [s for _, s in specs]
    n_params = sum(int(np.prod(s)) for _, s in specs)
    assert meta["n_params"] == n_params


def test_meta_trainable_subsets():
    full = _meta("ar_small_full_loss_b8_s64")
    lora = _meta("ar_small_lora_loss_b8_s64")
    prefix = _meta("ar_small_prefix_loss_b8_s64")
    base_names = {p["name"] for p in full["params"]}
    assert set(full["trainable"]) == base_names
    assert all(".lora_" in n for n in lora["trainable"])
    assert all(".prefix." in n for n in prefix["trainable"])
    # PEFT params come after base params (artifact ABI)
    lora_names = [p["name"] for p in lora["params"]]
    assert lora_names[: len(full["params"])] == [p["name"] for p in full["params"]]


def test_grad_meta_outputs_align_with_trainables():
    meta = _meta("ar_tiny_full_grad_b8_s64")
    outs = meta["outputs"]
    assert outs[0]["name"] == "loss" and outs[0]["shape"] == []
    grads = outs[1:]
    params = {p["name"]: p["shape"] for p in meta["params"]}
    assert len(grads) == len(meta["trainable"])
    for g, t in zip(grads, meta["trainable"]):
        assert g["name"] == f"grad.{t}"
        assert g["shape"] == params[t]


def test_hlo_text_mentions_entry_and_parses_shapes():
    path = os.path.join(ART_DIR, "ar_tiny_full_loss_b8_s64.hlo.txt")
    if not os.path.exists(path):
        pytest.skip("artifact not built")
    text = open(path).read()
    assert "ENTRY" in text
    meta = _meta("ar_tiny_full_loss_b8_s64")
    # every param tensor appears as a parameter of matching rank
    assert text.count("parameter(") >= len(meta["params"]) + 4


def test_lowered_loss_matches_eager():
    """Execute the lowered (stablehlo->XLA) computation in-process and
    compare against eager jax — the same artifact rust will run."""
    cfg = M.ModelConfig(family="ar", size="tiny")
    fn, _ = aot.build_fn(cfg, "loss")
    rng = np.random.default_rng(0)
    args = []
    for name, shape in M.param_specs(cfg):
        args.append(jnp.asarray(rng.normal(0, 0.02, shape).astype("float32")))
    b, s = 8, 64
    args.append(jnp.asarray(rng.integers(0, cfg.vocab, (b, s)).astype("int32")))
    args.append(jnp.asarray(rng.integers(0, cfg.vocab, (b, s)).astype("int32")))
    args.append(jnp.ones((b, s), jnp.float32))
    args.append(jnp.ones((b, s), jnp.float32))
    eager = fn(*args)
    jitted = jax.jit(fn)(*args)
    np.testing.assert_allclose(float(eager[0]), float(jitted[0]), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(eager[1]), np.asarray(jitted[1]),
                               rtol=1e-5, atol=1e-6)


def test_flops_estimate_monotone_in_size():
    f = {}
    for size in ("tiny", "small", "base", "large"):
        cfg = M.ModelConfig(family="ar", size=size)
        f[size] = aot.flops_forward(cfg, 8, 64)
    assert f["tiny"] < f["small"] < f["base"] < f["large"]
