"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes/dtypes/masks (the CORE correctness signal for the
kernels the AOT artifacts are built from).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels as K
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=10, deadline=None)


def _rand(rng, shape, dtype="float32"):
    return jnp.asarray(rng.normal(size=shape).astype(dtype))


# ---------------------------------------------------------------- attention
@settings(**SETTINGS)
@given(
    b=st.integers(1, 3),
    h=st.sampled_from([1, 2, 4]),
    sq=st.sampled_from([8, 16, 32, 64]),
    dh=st.sampled_from([8, 16, 32]),
    causal=st.booleans(),
    prefix=st.sampled_from([0, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(b, h, sq, dh, causal, prefix, seed):
    rng = np.random.default_rng(seed)
    sk = sq + prefix
    q = _rand(rng, (b, h, sq, dh))
    k = _rand(rng, (b, h, sk, dh))
    v = _rand(rng, (b, h, sk, dh))
    mask = np.ones((b, sk), "float32")
    # random padding on the non-prefix tail, keep at least one valid key
    pad = rng.integers(0, sq // 2, size=b)
    for i, p in enumerate(pad):
        if p:
            mask[i, sk - p:] = 0.0
    mask = jnp.asarray(mask)
    out = K.attention(q, k, v, mask, causal)
    want = ref.attention_ref(q, k, v, mask, causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_attention_fully_masked_rows_are_finite():
    # all keys masked -> kernel must emit zeros, not NaN
    b, h, s, dh = 1, 1, 8, 8
    rng = np.random.default_rng(0)
    q, k, v = (_rand(rng, (b, h, s, dh)) for _ in range(3))
    mask = jnp.zeros((b, s), jnp.float32)
    out = K.attention(q, k, v, mask, causal=False)
    assert np.isfinite(np.asarray(out)).all()


def test_attention_causality():
    """Future keys must not influence causal attention outputs."""
    b, h, s, dh = 1, 2, 16, 8
    rng = np.random.default_rng(1)
    q = _rand(rng, (b, h, s, dh))
    k = _rand(rng, (b, h, s, dh))
    v = _rand(rng, (b, h, s, dh))
    mask = jnp.ones((b, s), jnp.float32)
    out1 = K.attention(q, k, v, mask, causal=True)
    k2 = k.at[:, :, s // 2:, :].set(999.0)
    v2 = v.at[:, :, s // 2:, :].set(-999.0)
    out2 = K.attention(q, k2, v2, mask, causal=True)
    np.testing.assert_allclose(np.asarray(out1[:, :, : s // 2]),
                               np.asarray(out2[:, :, : s // 2]),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------- layernorm
@settings(**SETTINGS)
@given(
    r=st.sampled_from([1, 8, 64, 128]),
    d=st.sampled_from([16, 48, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_layernorm_matches_ref(r, d, seed):
    rng = np.random.default_rng(seed)
    x, g, b = _rand(rng, (r, d)), _rand(rng, (d,)), _rand(rng, (d,))
    out = K.layernorm(x, g, b)
    want = ref.layernorm_ref(x, g, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_layernorm_output_is_normalized():
    rng = np.random.default_rng(2)
    x = _rand(rng, (32, 64)) * 10 + 5
    out = np.asarray(K.layernorm(x, jnp.ones(64), jnp.zeros(64)))
    np.testing.assert_allclose(out.mean(-1), 0.0, atol=1e-5)
    np.testing.assert_allclose(out.std(-1), 1.0, atol=1e-2)


# ------------------------------------------------------------------- linear
@settings(**SETTINGS)
@given(
    m=st.sampled_from([8, 32, 64, 256]),
    k=st.sampled_from([16, 64, 128]),
    n=st.sampled_from([16, 96, 128]),
    act=st.sampled_from([None, "gelu"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_linear_matches_ref(m, k, n, act, seed):
    rng = np.random.default_rng(seed)
    x, w, b = _rand(rng, (m, k)), _rand(rng, (k, n)), _rand(rng, (n,))
    out = K.linear(x, w, b, act)
    want = ref.linear_ref(x, w, b, act)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_linear_k_accumulation():
    """K larger than block_k exercises the accumulate-over-k-blocks path."""
    rng = np.random.default_rng(3)
    x, w, b = _rand(rng, (16, 256)), _rand(rng, (256, 32)), _rand(rng, (32,))
    out = K.linear(x, w, b, None, block_k=64)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.linear_ref(x, w, b)),
                               rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------- softmax xent
@settings(**SETTINGS)
@given(
    r=st.sampled_from([8, 64, 128]),
    v=st.sampled_from([32, 128, 512]),
    seed=st.integers(0, 2**31 - 1),
)
def test_softmax_xent_matches_ref(r, v, seed):
    rng = np.random.default_rng(seed)
    logits = _rand(rng, (r, v)) * 3
    targets = jnp.asarray(rng.integers(0, v, size=(r,)).astype("int32"))
    mask = jnp.asarray((rng.random(r) > 0.3).astype("float32"))
    out = K.softmax_xent(logits, targets, mask)
    want = ref.softmax_xent_ref(logits[None], targets[None], mask[None])[0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_softmax_xent_uniform_logits_is_log_v():
    v = 128
    logits = jnp.zeros((4, v))
    targets = jnp.asarray([0, 1, 2, 3], jnp.int32)
    mask = jnp.ones(4)
    out = np.asarray(K.softmax_xent(logits, targets, mask))
    np.testing.assert_allclose(out, np.log(v), rtol=1e-6)


# --------------------------------------------------------------------- spsa
@settings(**SETTINGS)
@given(
    n=st.sampled_from([1, 7, 100, 4096, 5000]),
    eps=st.sampled_from([1e-3, 1e-1, -1e-2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_spsa_perturb_matches_ref(n, eps, seed):
    rng = np.random.default_rng(seed)
    t, z = _rand(rng, (n,)), _rand(rng, (n,))
    out = K.spsa_perturb(t, z, jnp.asarray([eps], jnp.float32))
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.spsa_perturb_ref(t, z, eps)),
                               rtol=1e-6, atol=1e-6)


def test_spsa_perturb_roundtrip():
    """Algorithm 1's +eps, -2eps, +eps sequence restores theta (fp error)."""
    rng = np.random.default_rng(4)
    t, z = _rand(rng, (1000,)), _rand(rng, (1000,))
    e = jnp.asarray([1e-3], jnp.float32)
    t1 = K.spsa_perturb(t, z, e)
    t2 = K.spsa_perturb(t1, z, -2 * e)
    t3 = K.spsa_perturb(t2, z, e)
    np.testing.assert_allclose(np.asarray(t3), np.asarray(t), atol=1e-6)
