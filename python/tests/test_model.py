"""L2 correctness: model shapes, loss semantics, PEFT wiring, grads,
pallas/ref path equivalence, and a jax-side MeZO sanity run."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

B, S = 4, 32


def make_params(cfg, seed=0, scale=0.02):
    rng = np.random.default_rng(seed)
    params = {}
    for name, shape in M.param_specs(cfg):
        if name.endswith((".g",)):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith((".b", ".bq", ".bk", ".bv", ".bo", ".b1", ".b2")):
            params[name] = jnp.zeros(shape, jnp.float32)
        elif ".lora_" in name and name.endswith(".b"):
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            params[name] = jnp.asarray(
                rng.normal(0, scale, shape).astype("float32"))
    return params


def make_batch(cfg, seed=0):
    rng = np.random.default_rng(seed + 100)
    ii = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)).astype("int32"))
    tg = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)).astype("int32"))
    lm = jnp.ones((B, S), jnp.float32)
    am = jnp.ones((B, S), jnp.float32)
    return ii, tg, lm, am


@pytest.mark.parametrize("family", ["ar", "mlm"])
def test_loss_near_log_vocab_at_init(family):
    cfg = M.ModelConfig(family=family, size="tiny")
    params = make_params(cfg)
    ii, tg, lm, am = make_batch(cfg)
    loss, per_ex = M.loss_fn(cfg, params, ii, tg, lm, am, use_pallas=False)
    assert per_ex.shape == (B,)
    assert abs(float(loss) - np.log(cfg.vocab)) < 0.5
    np.testing.assert_allclose(float(jnp.mean(per_ex)), float(loss), rtol=1e-5)


@pytest.mark.parametrize("family", ["ar", "mlm"])
def test_pallas_and_ref_paths_agree(family):
    cfg = M.ModelConfig(family=family, size="tiny")
    params = make_params(cfg, seed=1)
    ii, tg, lm, am = make_batch(cfg, seed=1)
    l_ref = M.loss_fn(cfg, params, ii, tg, lm, am, use_pallas=False)
    l_pal = M.loss_fn(cfg, params, ii, tg, lm, am, use_pallas=True)
    np.testing.assert_allclose(float(l_ref[0]), float(l_pal[0]), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(l_ref[1]), np.asarray(l_pal[1]),
                               rtol=1e-5, atol=1e-6)


def test_ar_is_causal_mlm_is_not():
    """Changing a future token changes AR per-example loss only for that
    example, and only positions before it stay fixed; MLM sees everything."""
    cfg_ar = M.ModelConfig(family="ar", size="tiny")
    params = make_params(cfg_ar, seed=2)
    ii, tg, lm, am = make_batch(cfg_ar, seed=2)
    # loss only on first half positions
    lm_half = lm.at[:, S // 2:].set(0.0)
    base, _ = M.loss_fn(cfg_ar, params, ii, tg, lm_half, am, False)
    ii2 = ii.at[:, -1].set((ii[:, -1] + 1) % cfg_ar.vocab)
    pert, _ = M.loss_fn(cfg_ar, params, ii2, tg, lm_half, am, False)
    np.testing.assert_allclose(float(base), float(pert), rtol=1e-6)

    cfg_mlm = M.ModelConfig(family="mlm", size="tiny")
    base_m, _ = M.loss_fn(cfg_mlm, params, ii, tg, lm_half, am, False)
    pert_m, _ = M.loss_fn(cfg_mlm, params, ii2, tg, lm_half, am, False)
    assert abs(float(base_m) - float(pert_m)) > 1e-7


def test_padding_mask_blocks_influence():
    cfg = M.ModelConfig(family="ar", size="tiny")
    params = make_params(cfg, seed=3)
    ii, tg, lm, am = make_batch(cfg, seed=3)
    am2 = am.at[:, S - 4:].set(0.0)
    lm2 = lm.at[:, S - 4:].set(0.0)
    base, _ = M.loss_fn(cfg, params, ii, tg, lm2, am2, False)
    ii2 = ii.at[:, S - 2].set(7)
    pert, _ = M.loss_fn(cfg, params, ii2, tg, lm2, am2, False)
    np.testing.assert_allclose(float(base), float(pert), rtol=1e-6)


def test_lora_zero_b_matches_base():
    """With B=0, LoRA model == base model exactly (Hu et al. init)."""
    cfg_l = M.ModelConfig(family="ar", size="tiny", tuning="lora")
    cfg_f = M.ModelConfig(family="ar", size="tiny", tuning="full")
    params = make_params(cfg_l, seed=4)  # lora .b tensors are zeros
    ii, tg, lm, am = make_batch(cfg_l, seed=4)
    l_lora, _ = M.loss_fn(cfg_l, params, ii, tg, lm, am, False)
    base = {n: v for n, v in params.items() if ".lora_" not in n}
    l_base, _ = M.loss_fn(cfg_f, base, ii, tg, lm, am, False)
    np.testing.assert_allclose(float(l_lora), float(l_base), rtol=1e-6)


def test_prefix_changes_loss_and_respects_shapes():
    cfg = M.ModelConfig(family="ar", size="tiny", tuning="prefix")
    params = make_params(cfg, seed=5)
    ii, tg, lm, am = make_batch(cfg, seed=5)
    l1, _ = M.loss_fn(cfg, params, ii, tg, lm, am, False)
    l1p, _ = M.loss_fn(cfg, params, ii, tg, lm, am, True)
    np.testing.assert_allclose(float(l1), float(l1p), rtol=1e-5)
    params2 = dict(params)
    params2["layer0.prefix.k"] = params["layer0.prefix.k"] + 1.0
    l2, _ = M.loss_fn(cfg, params2, ii, tg, lm, am, False)
    assert abs(float(l1) - float(l2)) > 1e-8


@pytest.mark.parametrize("tuning", ["full", "lora", "prefix"])
def test_grad_matches_finite_difference(tuning):
    cfg = M.ModelConfig(family="ar", size="tiny", tuning=tuning)
    params = make_params(cfg, seed=6)
    ii, tg, lm, am = make_batch(cfg, seed=6)
    loss, grads = M.grad_fn(cfg, params, ii, tg, lm, am)
    tnames = M.trainable_names(cfg)
    assert len(grads) == len(tnames)
    # finite-difference check on one scalar of one tensor
    name = tnames[0]
    idx = (0,) * params[name].ndim
    eps = 1e-3
    p_plus = dict(params)
    p_plus[name] = params[name].at[idx].add(eps)
    p_minus = dict(params)
    p_minus[name] = params[name].at[idx].add(-eps)
    lp, _ = M.loss_fn(cfg, p_plus, ii, tg, lm, am, False)
    lm_, _ = M.loss_fn(cfg, p_minus, ii, tg, lm, am, False)
    fd = (float(lp) - float(lm_)) / (2 * eps)
    g = float(grads[tnames.index(name)][idx])
    assert abs(fd - g) < 5e-3, (fd, g)


def test_logits_features_shapes():
    cfg = M.ModelConfig(family="mlm", size="tiny")
    params = make_params(cfg, seed=7)
    ii, _, _, am = make_batch(cfg, seed=7)
    logits, hidden = M.logits_features_fn(cfg, params, ii, am, False)
    assert logits.shape == (B, S, cfg.vocab)
    assert hidden.shape == (B, S, cfg.d_model)


def test_kv_activations_shapes():
    cfg = M.ModelConfig(family="ar", size="tiny", tuning="prefix")
    params = make_params(cfg, seed=8)
    ii = jnp.asarray(np.arange(8, dtype="int32")[None])
    am = jnp.ones((1, 8), jnp.float32)
    outs = M.kv_activations_fn(cfg, params, ii, am)
    assert len(outs) == 2 * cfg.n_layers
    for o in outs:
        assert o.shape == (8, cfg.d_model)


def test_mezo_sgd_decreases_loss_jax_side():
    """Jax-side Algorithm 1 sanity: MeZO reduces loss on a fixed batch."""
    cfg = M.ModelConfig(family="ar", size="tiny")
    params = make_params(cfg, seed=9)
    ii, tg, lm, am = make_batch(cfg, seed=9)
    loss_fn = jax.jit(lambda p: M.loss_fn(cfg, p, ii, tg, lm, am, False)[0])
    names = M.trainable_names(cfg)
    eps, lr = 1e-3, 3e-3
    key = jax.random.PRNGKey(0)
    l0 = float(loss_fn(params))
    for step in range(60):
        key, sub = jax.random.split(key)
        zs = {n: jax.random.normal(jax.random.fold_in(sub, i),
                                   params[n].shape) for i, n in enumerate(names)}
        lp = float(loss_fn({**params, **{n: params[n] + eps * zs[n] for n in names}}))
        lm_ = float(loss_fn({**params, **{n: params[n] - eps * zs[n] for n in names}}))
        g = (lp - lm_) / (2 * eps)
        params = {**params, **{n: params[n] - lr * g * zs[n] for n in names}}
    l1 = float(loss_fn(params))
    assert l1 < l0 - 0.01, (l0, l1)


def test_fused_step_runs_and_matches_semantics():
    cfg = M.ModelConfig(family="ar", size="tiny")
    params = make_params(cfg, seed=10)
    ii, tg, lm, am = make_batch(cfg, seed=10)
    seed = jnp.asarray([7], jnp.int32)
    eps = jnp.asarray([1e-3], jnp.float32)
    lr = jnp.asarray([1e-2], jnp.float32)
    out = M.mezo_fused_step_fn(cfg, params, ii, tg, lm, am, seed, eps, lr)
    tnames = M.trainable_names(cfg)
    assert len(out) == len(tnames) + 3
    lp, lm_, pg = (float(out[-3]), float(out[-2]), float(out[-1]))
    np.testing.assert_allclose(pg, (lp - lm_) / (2 * 1e-3), rtol=1e-3)
    # updated params differ from originals
    assert float(jnp.abs(out[0] - params[tnames[0]]).max()) > 0
